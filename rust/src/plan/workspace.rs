//! Pre-sized execution arena for [`CompiledPlan`](super::CompiledPlan).
//!
//! A workspace owns two ping-pong (mean, aux) buffer pairs sized at the
//! network's high-water mark plus one scratch region for the im2col conv
//! lowering, all allocated once at plan time. Steady-state
//! `CompiledPlan::execute` calls write every intermediate activation into
//! these buffers and perform **zero** heap allocation — serial and
//! parallel alike. Parallel steps need no execute-time task structures
//! here: their disjoint tile partitions are pre-bound in the plan's steps
//! at compile time and gang-dispatched by reference
//! (`ThreadPool::run_tasks`), with each tile carving its `&mut` chunk out
//! of these buffers via raw-pointer splits. Only the deliberately naive
//! `Mkn` baseline schedule (Table 2 row 1) still allocates inside its
//! loop body.

/// One (mean, aux) activation buffer of the ping-pong pair.
#[derive(Debug, Default)]
pub(crate) struct BufPair {
    pub mu: Vec<f32>,
    pub aux: Vec<f32>,
}

impl BufPair {
    fn with_len(len: usize) -> Self {
        Self { mu: vec![0.0; len], aux: vec![0.0; len] }
    }

    fn ensure(&mut self, len: usize) {
        if self.mu.len() < len {
            // One-time growth to the plan's high-water mark; steady-state
            // calls take the len-check fast path above.
            self.mu.resize(len, 0.0); // lint: allow(alloc) — cold growth
            self.aux.resize(len, 0.0); // lint: allow(alloc) — cold growth
        }
    }
}

/// Plan execution arena: ping-pong activation buffers + conv scratch +
/// the packed (u16) staging buffer mixed-precision plans narrow their
/// inter-layer activations through (f16/bf16 storage bits; empty for
/// all-f32 plans, which never touch it).
#[derive(Debug, Default)]
pub struct Workspace {
    pub(crate) a: BufPair,
    pub(crate) b: BufPair,
    pub(crate) scratch: Vec<f32>,
    pub(crate) packed: Vec<u16>,
}

impl Workspace {
    /// Arena with `hwm` floats per moment buffer, `scratch_len` floats
    /// of conv scratch, and `packed_len` u16s of mixed-precision
    /// activation staging (0 for all-f32 plans).
    pub fn with_capacity(hwm: usize, scratch_len: usize, packed_len: usize) -> Self {
        Self {
            a: BufPair::with_len(hwm),
            b: BufPair::with_len(hwm),
            scratch: vec![0.0; scratch_len],
            packed: vec![0; packed_len],
        }
    }

    /// Grow to at least the requested sizes. No-op (and allocation-free)
    /// when already large enough — the steady-state path.
    pub(crate) fn ensure(&mut self, hwm: usize, scratch_len: usize, packed_len: usize) {
        self.a.ensure(hwm);
        self.b.ensure(hwm);
        if self.scratch.len() < scratch_len {
            // lint: allow(alloc) — cold growth path, same rationale as BufPair.
            self.scratch.resize(scratch_len, 0.0);
        }
        if self.packed.len() < packed_len {
            // lint: allow(alloc) — cold growth path, same rationale as BufPair.
            self.packed.resize(packed_len, 0);
        }
    }

    /// Per-buffer capacity in floats (the plan's high-water mark once
    /// sized by [`CompiledPlan::workspace`](super::CompiledPlan::workspace)).
    pub fn capacity(&self) -> usize {
        self.a.mu.len()
    }

    /// Conv im2col scratch capacity in floats.
    pub fn scratch_capacity(&self) -> usize {
        self.scratch.len()
    }

    /// Mixed-precision activation staging capacity in u16 storage words
    /// (0 for all-f32 plans).
    pub fn packed_capacity(&self) -> usize {
        self.packed.len()
    }

    /// Total owned floats (both ping-pong pairs + scratch + the packed
    /// staging buffer at two u16 words per float) — the plan's entire
    /// steady-state memory footprint.
    pub fn total_floats(&self) -> usize {
        4 * self.a.mu.len() + self.scratch.len() + self.packed.len().div_ceil(2)
    }
}
