//! Static lowering: compile `Arch + PosteriorWeights + Schedules` into an
//! executable plan for one fixed batch size — the paper's
//! compile-then-execute architecture (TVM lowers the whole graph once,
//! schedules bound per operator workload and per mini-batch size; the
//! runtime just executes).
//!
//! A [`CompiledPlan`] is a flat sequence of pre-bound [`Step`]s with
//!
//! * all shapes and representation conversions resolved at plan time —
//!   conversions become explicit steps inserted exactly where the layer
//!   representation contracts disagree (labelled `Convert@<layer>` so the
//!   profiler attributes the paper's "tooling" overhead to the layer it
//!   feeds), the first-layer `squared()` is folded into the Eq. 13 kernel
//!   (whose activation-aux operand is ignored), and `Flatten` vanishes
//!   entirely (it is a shape-only relabeling of contiguous memory);
//! * a [`Workspace`] arena sized at plan time: two ping-pong (mean, aux)
//!   buffers at the network's high-water mark plus im2col scratch, so
//!   steady-state [`CompiledPlan::execute`] performs **zero** heap
//!   allocation — serial *and* parallel, tiled or not;
//! * one schedule bound per *compute step* from the per-layer schedule
//!   table ([`Schedules::per_layer`]), realizing the paper's
//!   per-operator-workload tuning: the MLP's 784→100 and 100→10 layers
//!   can carry different tiles/unrolls — and, since PR 5, different
//!   [`Isa`](crate::ops::Isa) knobs: compute steps bind their schedule's
//!   ISA (subject to the `Schedules::isa_override` serve/tune `--isa`
//!   policy), ReLU and the vectorized pool bind the plan-wide elementwise
//!   ISA, and the one-time runtime detector resolves `Native` to
//!   AVX2+FMA / NEON / scalar at execution (`PFP_FORCE_SCALAR=1` forces
//!   the fallback);
//! * **fused epilogues** (PR 8): under the plan's fusion policy
//!   ([`FusePolicy`](crate::model::FusePolicy)), a dense/conv step
//!   directly followed by the moment-matched ReLU absorbs it — and, when
//!   the ReLU's E2 output would immediately be converted back to a
//!   variance for the next consumer (max-pool or the network output),
//!   the conversion too — into a single step whose kernel applies the
//!   elementwise chain on each cache-hot output tile
//!   ([`Epilogue`](crate::ops::Epilogue)). This removes the 2–3
//!   full-tensor ping-pong round trips per layer that standalone
//!   relu/convert steps cost; the buffer high-water mark is recomputed
//!   over the fused step list (same value — the absorbed ops are
//!   same-length — but fused layers skip a buffer generation). Fused
//!   steps keep the producing layer's Table-4 label and op type, and
//!   within one ISA they are bit-identical to the unfused lowering (the
//!   elementwise kernels are position-independent); the serve/tune
//!   `--fuse on|off|auto` flag drives the policy, default off for the
//!   stock schedules so plan == interpreter stays bitwise;
//! * the step's **work partition** resolved at plan time: each parallel
//!   step carries a pre-bound list of disjoint tile tasks (row ranges for
//!   dense, patch-row + output-plane ranges for conv's im2col lowering,
//!   element ranges for ReLU, plane ranges for max-pool — split with
//!   `split_ranges`), sized from the bound schedule's `threads` knob or
//!   the plan-wide [`Schedules::plan_threads`] override. At execute time
//!   the tiles are gang-dispatched over the plan's persistent pool
//!   (`ThreadPool::run_tasks`) with no boxing and no `Vec` growth, and
//!   because work is partitioned over rows — never over the reduction —
//!   planned-parallel output is **bit-identical** to planned-serial.
//!
//! `PfpExecutor` / `DetExecutor` build-and-cache plans keyed by batch
//! size, and the serving `NativePfpBackend` maps every dynamic-batcher
//! bucket size to its own cached plan — the paper's per-mini-batch-size
//! compiled executables, end to end.

pub mod workspace;

pub use workspace::Workspace;

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::model::{pack_tensor, Arch, LayerSpec, PosteriorWeights, Schedules};
use crate::ops::conv::{conv_kernel_packed_tiled_into, conv_kernel_tiled_into, ConvShape};
use crate::ops::dense::{
    dense_kernel_packed_tiled_into, dense_kernel_tiled_into, DenseSlices, FirstLayer, JointEq12,
    MeanOnly, PackedDenseSlices,
};
use crate::ops::maxpool::{
    det_maxpool2_tiled_into, pfp_maxpool2_tiled_into, pfp_maxpool_generic_into,
};
use crate::ops::relu::pfp_relu_tiled_into;
use crate::ops::simd::{self, PackedSlice};
use crate::ops::{Epilogue, Schedule};
use crate::profiling::Profiler;
use crate::tensor::{convert_in_place, Rep};
use crate::util::half::Precision;
use crate::util::threadpool::{split_ranges, DisjointMut, ThreadPool};

use self::workspace::BufPair;

/// What the plan computes: the probabilistic forward pass (mean +
/// variance moments) or the deterministic baseline (means only; the aux
/// half of the output is unspecified).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanMode {
    Pfp,
    Det,
}

/// Plan-time work partition: split `units` (rows / patch rows / planes /
/// elements, per step kind) into at most `tasks` disjoint contiguous
/// ranges via [`split_ranges`]. Zero or one effective task means the step
/// runs serially — an empty vector, so serial plans carry no partition
/// state at all. This is the partition the tuner's planned-executor
/// measurements use too, so tuning records describe exactly what runs.
pub fn tile_ranges(units: usize, tasks: usize) -> Vec<std::ops::Range<usize>> {
    if tasks <= 1 || units <= 1 {
        return Vec::new();
    }
    split_ranges(units, tasks)
}

/// One pre-bound executable step.
#[derive(Clone, Debug)]
struct Step {
    kind: StepKind,
    /// Schedule bound at plan time (compute steps only; `threads` is
    /// forced to 1 — the tile partition below is the parallelization).
    sched: Schedule,
    /// Pre-bound disjoint tile tasks: row ranges (dense), patch-row
    /// ranges (conv phase 1), element ranges (relu), plane ranges
    /// (max-pool). Empty = serial.
    tiles: Vec<std::ops::Range<usize>>,
    /// Profiler label: the layer's Table-4 name, or `Convert@<layer>`.
    label: String,
    op_type: &'static str,
    /// Fused elementwise chain (dense/conv steps only): the kernel
    /// applies it per cache-hot output tile, replacing the standalone
    /// relu (and possibly convert) steps the pattern matcher absorbed.
    epilogue: Epilogue,
    in_len: usize,
    out_len: usize,
    /// Resolved storage precision of the mean path (mu weight operand +
    /// mu output activations) and the variance path (aux weight operand +
    /// aux output activations) — the tentpole mixed-precision knobs. Both
    /// `F32` on non-compute steps and under stock schedules, which lower
    /// and execute exactly as before this knob existed.
    mean_prec: Precision,
    var_prec: Precision,
    /// Packed (u16) weight copies, converted once at compile time by
    /// [`pack_tensor`]: mu weights at `mean_prec`, aux weights at
    /// `var_prec`. `None` = the step borrows the f32 tensor directly.
    packed_wm: Option<Arc<Vec<u16>>>,
    packed_wa: Option<Arc<Vec<u16>>>,
}

#[derive(Clone, Debug)]
enum StepKind {
    /// Scheduled dense kernel. `first` = PFP Eq. 13 (deterministic
    /// input); in det mode the mean-only accumulator runs regardless.
    Dense { w: usize, first: bool, m: usize, k: usize, n: usize },
    /// Scheduled conv kernel via im2col into workspace scratch.
    /// `scatter` is the col2im phase's output-plane partition.
    Conv { w: usize, first: bool, shape: ConvShape, scatter: Vec<std::ops::Range<usize>> },
    /// Moment-matched ReLU (consumes variance, produces E[x^2]).
    Relu,
    /// Deterministic ReLU, in place on the mean buffer.
    ReluDet,
    /// Gaussian max-pool k=2/stride-2 (variance to variance).
    MaxPool { vectorized: bool, n: usize, c: usize, h: usize, w: usize },
    /// Deterministic max-pool (means only).
    MaxPoolDet { n: usize, c: usize, h: usize, w: usize },
    /// Explicit representation conversion, in place on the aux buffer.
    Convert { from: Rep, to: Rep },
}

/// The dense-kernel workload behind one compute step (conv reports its
/// im2col'd dims) — what the tuner measures to fill the per-layer
/// schedule table with each layer's *actual* shape.
#[derive(Clone, Debug)]
pub struct DenseWorkload {
    /// Index into `PosteriorWeights::layers` / `Schedules::per_layer`.
    pub compute_idx: usize,
    /// Records op key: `"dense"` or `"conv"`.
    pub op: &'static str,
    /// Table-4 layer label (e.g. `"Dense 2"`).
    pub label: String,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// The elementwise chain this plan fused into the step
    /// ([`Epilogue::None`] when lowered unfused). The tuner measures
    /// fused candidates with exactly this epilogue so the record
    /// describes the kernel that would actually run.
    pub ep: Epilogue,
}

/// A network lowered to a flat step sequence for one batch size.
pub struct CompiledPlan {
    pub arch_name: String,
    pub mode: PlanMode,
    pub batch: usize,
    steps: Vec<Step>,
    weights: Arc<PosteriorWeights>,
    pool: Arc<ThreadPool>,
    /// Expected input floats: `batch * input_len`.
    in_len: usize,
    /// Output classes (columns of the `[batch, classes]` result).
    classes: usize,
    /// Final output floats: `batch * classes`.
    out_len: usize,
    /// Ping-pong buffer high-water mark (floats per moment buffer).
    hwm: usize,
    /// Conv im2col scratch requirement (floats).
    scratch_len: usize,
    /// Mixed-precision activation staging requirement (u16 words); 0 for
    /// all-f32 plans.
    packed_len: usize,
}

impl CompiledPlan {
    /// Lower the network for a fixed `batch`. Shapes, conversions, and
    /// per-layer schedules are resolved here, once; `execute` never
    /// inspects the architecture again.
    pub fn compile(
        arch: &Arch,
        weights: Arc<PosteriorWeights>,
        schedules: &Schedules,
        batch: usize,
        mode: PlanMode,
    ) -> Result<Self> {
        if batch == 0 {
            return Err(Error::Shape("plan batch must be > 0".into()));
        }
        if arch.compute_layers().len() != weights.layers.len() {
            return Err(Error::Shape(format!(
                "arch {} has {} compute layers, weights have {}",
                arch.name,
                arch.compute_layers().len(),
                weights.layers.len()
            )));
        }
        let labels = arch.layer_labels();
        let mut steps: Vec<Step> = Vec::new();
        // per-batch-element shape and representation of the current state
        let mut shape: Vec<usize> = arch.input_shape.clone();
        let mut rep: Option<Rep> = None;
        let mut compute_idx = 0usize;
        let mut cur_len = batch * arch.input_len();
        let mut hwm = 0usize;
        let mut scratch_len = 0usize;
        // u16 staging high-water mark for mixed-precision activation
        // storage; stays 0 (no buffer at all) when every step is f32
        let mut packed_hwm = 0usize;
        let pfp = mode == PlanMode::Pfp;
        // Effective worker count per step: the plan-wide override when
        // set, else the knob the step's schedule (or Schedules field)
        // carries.
        let plan_threads = schedules.plan_threads;
        let step_tasks =
            |sched_threads: usize| if plan_threads > 0 { plan_threads } else { sched_threads };

        for (li, layer) in arch.layers.iter().enumerate() {
            match layer {
                LayerSpec::Dense { d_in, d_out } => {
                    let k: usize = shape.iter().product();
                    if k != *d_in {
                        return Err(Error::Shape(format!(
                            "{}: expects {} input features, graph carries {}",
                            labels[li], d_in, k
                        )));
                    }
                    let lw = &weights.layers[compute_idx];
                    if lw.w_mu.shape() != [*d_out, *d_in] {
                        return Err(Error::Shape(format!(
                            "{}: weight shape {:?} != [{}, {}]",
                            labels[li],
                            lw.w_mu.shape(),
                            d_out,
                            d_in
                        )));
                    }
                    let first = rep.is_none();
                    if pfp && !first && rep != Some(Rep::E2) {
                        steps.push(convert_step(rep.unwrap(), Rep::E2, cur_len, &labels[li]));
                        rep = Some(Rep::E2);
                    }
                    let out_len = batch * d_out;
                    let sched = schedules.layer_schedule(compute_idx, layer);
                    let (mean_prec, var_prec) = step_precisions(&sched, schedules, pfp);
                    let wa_tensor = if !pfp {
                        &lw.w_mu
                    } else if first {
                        &lw.w_var
                    } else {
                        &lw.w_e2
                    };
                    let packed_wm = pack_tensor(&lw.w_mu, mean_prec);
                    let packed_wa = pack_tensor(wa_tensor, var_prec);
                    if !mean_prec.is_f32() || !var_prec.is_f32() {
                        packed_hwm = packed_hwm.max(out_len);
                    }
                    steps.push(Step {
                        kind: StepKind::Dense {
                            w: compute_idx,
                            first: pfp && first,
                            m: batch,
                            k,
                            n: *d_out,
                        },
                        tiles: tile_ranges(batch, step_tasks(sched.threads)),
                        sched: sched.with_threads(1),
                        label: labels[li].clone(),
                        op_type: "dense",
                        epilogue: Epilogue::None,
                        in_len: cur_len,
                        out_len,
                        mean_prec,
                        var_prec,
                        packed_wm,
                        packed_wa,
                    });
                    shape = vec![*d_out];
                    rep = Some(Rep::Var);
                    cur_len = out_len;
                    compute_idx += 1;
                }
                LayerSpec::Conv { in_ch, out_ch, k } => {
                    if shape.len() != 3 || shape[0] != *in_ch {
                        return Err(Error::Shape(format!(
                            "{}: expects [{}; H; W] input, graph carries {:?}",
                            labels[li], in_ch, shape
                        )));
                    }
                    let (h, w) = (shape[1], shape[2]);
                    if h < *k || w < *k {
                        return Err(Error::Shape(format!(
                            "{}: {}x{} kernel over {}x{} input",
                            labels[li], k, k, h, w
                        )));
                    }
                    let lw = &weights.layers[compute_idx];
                    if lw.w_mu.shape() != [*out_ch, *in_ch, *k, *k] {
                        return Err(Error::Shape(format!(
                            "{}: weight shape {:?} != [{}, {}, {}, {}]",
                            labels[li],
                            lw.w_mu.shape(),
                            out_ch,
                            in_ch,
                            k,
                            k
                        )));
                    }
                    let first = rep.is_none();
                    if pfp && !first && rep != Some(Rep::E2) {
                        steps.push(convert_step(rep.unwrap(), Rep::E2, cur_len, &labels[li]));
                        rep = Some(Rep::E2);
                    }
                    let cs = ConvShape {
                        n: batch,
                        c: *in_ch,
                        h,
                        w,
                        o: *out_ch,
                        kh: *k,
                        kw: *k,
                    };
                    // Eq. 13 (and det mean-only) aliases its ignored aux
                    // patches onto the mean patches: one im2col, not two.
                    let shared_aux = !pfp || first;
                    scratch_len = scratch_len.max(cs.scratch_len(shared_aux));
                    let out_len = cs.out_len();
                    let sched = schedules.layer_schedule(compute_idx, layer);
                    let tasks = step_tasks(sched.threads);
                    let (mean_prec, var_prec) = step_precisions(&sched, schedules, pfp);
                    let wa_tensor = if !pfp {
                        &lw.w_mu
                    } else if first {
                        &lw.w_var
                    } else {
                        &lw.w_e2
                    };
                    let packed_wm = pack_tensor(&lw.w_mu, mean_prec);
                    let packed_wa = pack_tensor(wa_tensor, var_prec);
                    if !mean_prec.is_f32() || !var_prec.is_f32() {
                        packed_hwm = packed_hwm.max(out_len);
                    }
                    steps.push(Step {
                        kind: StepKind::Conv {
                            w: compute_idx,
                            first: pfp && first,
                            shape: cs,
                            scatter: tile_ranges(batch * *out_ch, tasks),
                        },
                        tiles: tile_ranges(cs.rows(), tasks),
                        sched: sched.with_threads(1),
                        label: labels[li].clone(),
                        op_type: "conv2d",
                        epilogue: Epilogue::None,
                        in_len: cur_len,
                        out_len,
                        mean_prec,
                        var_prec,
                        packed_wm,
                        packed_wa,
                    });
                    shape = vec![*out_ch, cs.oh(), cs.ow()];
                    rep = Some(Rep::Var);
                    cur_len = out_len;
                    compute_idx += 1;
                }
                LayerSpec::Relu => {
                    if rep.is_none() {
                        return Err(Error::Shape(format!(
                            "{}: activation before first compute layer",
                            labels[li]
                        )));
                    }
                    if pfp {
                        if rep != Some(Rep::Var) && !absorb_var_convert(&mut steps) {
                            steps.push(convert_step(
                                rep.unwrap(),
                                Rep::Var,
                                cur_len,
                                &labels[li],
                            ));
                        }
                        // PR 8 pattern match: a moment-matched ReLU whose
                        // variance input is the directly preceding
                        // dense/conv output (no convert in between) folds
                        // into that step's kernel epilogue when the
                        // fusion policy allows it — no standalone relu
                        // step, no ping-pong round trip.
                        let fusable = steps.last().is_some_and(|s| {
                            matches!(
                                s.kind,
                                StepKind::Dense { .. } | StepKind::Conv { .. }
                            ) && s.epilogue == Epilogue::None
                                && schedules.step_fuses(&s.sched)
                        });
                        if fusable {
                            let last = steps.last_mut().unwrap();
                            last.epilogue = Epilogue::Relu;
                            // reflect fusion in the bound schedule so the
                            // step's tag() reads `+fuse` whichever policy
                            // (On vs Auto+knob) enabled it
                            last.sched.fuse = true;
                        } else {
                            steps.push(Step {
                                kind: StepKind::Relu,
                                // the elementwise moment-matching kernels
                                // bind the plan-wide ISA policy (Native
                                // unless overridden — erf/exp dominate
                                // this step)
                                sched: Schedule::baseline()
                                    .with_isa(schedules.elementwise_isa()),
                                tiles: tile_ranges(
                                    cur_len,
                                    step_tasks(schedules.relu_threads),
                                ),
                                label: labels[li].clone(),
                                op_type: "relu",
                                epilogue: Epilogue::None,
                                in_len: cur_len,
                                out_len: cur_len,
                                mean_prec: Precision::F32,
                                var_prec: Precision::F32,
                                packed_wm: None,
                                packed_wa: None,
                            });
                        }
                        rep = Some(Rep::E2);
                    } else {
                        steps.push(Step {
                            kind: StepKind::ReluDet,
                            sched: Schedule::baseline(),
                            tiles: tile_ranges(cur_len, step_tasks(schedules.relu_threads)),
                            label: labels[li].clone(),
                            op_type: "relu",
                            epilogue: Epilogue::None,
                            in_len: cur_len,
                            out_len: cur_len,
                            mean_prec: Precision::F32,
                            var_prec: Precision::F32,
                            packed_wm: None,
                            packed_wa: None,
                        });
                    }
                }
                LayerSpec::MaxPool2 => {
                    if rep.is_none() || shape.len() != 3 {
                        return Err(Error::Shape(format!(
                            "{}: pool needs a [C; H; W] state, got {:?}",
                            labels[li], shape
                        )));
                    }
                    let (c, h, w) = (shape[0], shape[1], shape[2]);
                    let out_len = batch * c * (h / 2) * (w / 2);
                    if pfp {
                        if rep != Some(Rep::Var) && !absorb_var_convert(&mut steps) {
                            steps.push(convert_step(
                                rep.unwrap(),
                                Rep::Var,
                                cur_len,
                                &labels[li],
                            ));
                        }
                        // the generic (non-vectorized) pool is the Table-3
                        // slow baseline and stays serial by design
                        let pool_tiles = if schedules.vectorized_pool {
                            tile_ranges(batch * c, step_tasks(schedules.maxpool_threads))
                        } else {
                            Vec::new()
                        };
                        steps.push(Step {
                            kind: StepKind::MaxPool {
                                vectorized: schedules.vectorized_pool,
                                n: batch,
                                c,
                                h,
                                w,
                            },
                            sched: Schedule::baseline()
                                .with_isa(schedules.elementwise_isa()),
                            tiles: pool_tiles,
                            label: labels[li].clone(),
                            op_type: "maxpool",
                            epilogue: Epilogue::None,
                            in_len: cur_len,
                            out_len,
                            mean_prec: Precision::F32,
                            var_prec: Precision::F32,
                            packed_wm: None,
                            packed_wa: None,
                        });
                        rep = Some(Rep::Var);
                    } else {
                        steps.push(Step {
                            kind: StepKind::MaxPoolDet { n: batch, c, h, w },
                            sched: Schedule::baseline(),
                            tiles: tile_ranges(
                                batch * c,
                                step_tasks(schedules.maxpool_threads),
                            ),
                            label: labels[li].clone(),
                            op_type: "maxpool",
                            epilogue: Epilogue::None,
                            in_len: cur_len,
                            out_len,
                            mean_prec: Precision::F32,
                            var_prec: Precision::F32,
                            packed_wm: None,
                            packed_wa: None,
                        });
                    }
                    shape = vec![c, h / 2, w / 2];
                    cur_len = out_len;
                }
                // Shape-only relabeling of contiguous row-major memory:
                // no step is emitted, the runtime never sees it.
                LayerSpec::Flatten => {
                    shape = vec![shape.iter().product()];
                }
            }
            hwm = hwm.max(cur_len);
        }

        if rep.is_none() {
            return Err(Error::Shape(format!(
                "arch {} has no compute layers",
                arch.name
            )));
        }
        // the executor contract returns (mean, variance) moments
        if pfp && rep != Some(Rep::Var) && !absorb_var_convert(&mut steps) {
            steps.push(convert_step(rep.unwrap(), Rep::Var, cur_len, "output"));
        }

        let classes: usize = shape.iter().product();
        Ok(Self {
            arch_name: arch.name.clone(),
            mode,
            batch,
            steps,
            weights,
            pool: Arc::clone(&schedules.pool),
            in_len: batch * arch.input_len(),
            classes,
            out_len: cur_len,
            hwm,
            scratch_len,
            packed_len: packed_hwm,
        })
    }

    /// A workspace sized exactly for this plan.
    pub fn workspace(&self) -> Workspace {
        Workspace::with_capacity(self.hwm, self.scratch_len, self.packed_len)
    }

    /// Output geometry: `[batch, classes]`.
    pub fn out_shape(&self) -> (usize, usize) {
        (self.batch, self.classes)
    }

    /// Number of lowered steps (compute + activation + pool + explicit
    /// conversions).
    pub fn num_steps(&self) -> usize {
        self.steps.len()
    }

    /// (label, op_type) per step, in execution order — the resolved
    /// program, conversions included.
    pub fn step_labels(&self) -> Vec<(String, &'static str)> {
        self.steps.iter().map(|s| (s.label.clone(), s.op_type)).collect()
    }

    /// Steps lowered with a parallel tile partition (>1 pre-bound tile
    /// task). Zero for a serial plan; lowering with
    /// [`Schedules::plan_threads`] > 1 (or schedules carrying `threads`
    /// > 1) partitions every step with enough units to split.
    pub fn num_parallel_steps(&self) -> usize {
        self.steps.iter().filter(|s| s.tiles.len() > 1).count()
    }

    /// Compute steps that absorbed a following elementwise chain (PR 8
    /// fusion). Zero when the fusion policy resolved to off for every
    /// step, or the program had no fusable pattern.
    pub fn num_fused_steps(&self) -> usize {
        self.steps.iter().filter(|s| s.epilogue != Epilogue::None).count()
    }

    /// Compute steps lowered with mixed-precision (f16/bf16) storage on
    /// at least one moment path. Zero under stock schedules — those plans
    /// carry no packed weights and no staging buffer at all.
    pub fn num_packed_steps(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| !s.mean_prec.is_f32() || !s.var_prec.is_f32())
            .count()
    }

    /// Weight tensors converted to packed u16 storage at compile time —
    /// the registry's `packed_tensors` metadata column counts these
    /// across resident plans.
    pub fn packed_tensors(&self) -> usize {
        self.steps
            .iter()
            .map(|s| s.packed_wm.is_some() as usize + s.packed_wa.is_some() as usize)
            .sum()
    }

    /// The dense-kernel workload of every compute step (conv steps report
    /// their im2col'd dims) — the tuner's per-layer search targets.
    pub fn dense_workloads(&self) -> Vec<DenseWorkload> {
        self.steps
            .iter()
            .filter_map(|s| match &s.kind {
                StepKind::Dense { w, m, k, n, .. } => Some(DenseWorkload {
                    compute_idx: *w,
                    op: "dense",
                    label: s.label.clone(),
                    m: *m,
                    k: *k,
                    n: *n,
                    ep: s.epilogue,
                }),
                StepKind::Conv { w, shape, .. } => Some(DenseWorkload {
                    compute_idx: *w,
                    op: "conv",
                    label: s.label.clone(),
                    m: shape.rows(),
                    k: shape.kk(),
                    n: shape.o,
                    ep: s.epilogue,
                }),
                _ => None,
            })
            .collect()
    }

    /// Run the plan on `x` (`batch * input_len` floats, row-major, any
    /// input rank — shapes were resolved at compile time). Returns the
    /// output moment slices `[batch, classes]` borrowed from the
    /// workspace: mean and variance in PFP mode; in det mode the second
    /// slice is unspecified. Allocation-free at steady state, serial and
    /// parallel alike: parallel steps gang-dispatch their pre-bound tile
    /// tasks over the plan's pool (`ThreadPool::run_tasks` — no boxing,
    /// no `Vec` growth), and because tiles partition rows, never the
    /// reduction, the output is bit-identical at every tile count.
    /// `profiler` (when enabled) attributes every step, conversions under
    /// their `Convert@<layer>` label.
    pub fn execute<'w>(
        &self,
        x: &[f32],
        ws: &'w mut Workspace,
        profiler: &mut Profiler,
    ) -> (&'w [f32], &'w [f32]) {
        assert_eq!(
            x.len(),
            self.in_len,
            "plan {} b{} expects {} input floats",
            self.arch_name,
            self.batch,
            self.in_len
        );
        ws.ensure(self.hwm, self.scratch_len, self.packed_len);
        let Workspace { a, b, scratch, packed } = ws;
        let pool = &self.pool;
        // Ping-pong state: until the first compute step the state is the
        // caller's `x`; afterwards it lives in buffer A or B.
        let mut cur_a = false;
        let mut first_done = false;

        for step in &self.steps {
            match &step.kind {
                StepKind::Convert { from, to } => {
                    let cur = if cur_a { &mut *a } else { &mut *b };
                    let mu = &cur.mu[..step.in_len];
                    let aux = &mut cur.aux[..step.in_len];
                    profiler.record(&step.label, step.op_type, || {
                        convert_in_place(mu, aux, *from, *to)
                    });
                }
                StepKind::ReluDet => {
                    let cur = if cur_a { &mut *a } else { &mut *b };
                    let mu = &mut cur.mu[..step.in_len];
                    profiler.record(&step.label, step.op_type, || {
                        if step.tiles.len() <= 1 {
                            for v in mu.iter_mut() {
                                *v = v.max(0.0);
                            }
                        } else {
                            let parts = DisjointMut::new(mu);
                            pool.run_tasks(step.tiles.len(), &|ti| {
                                let r = step.tiles[ti].clone();
                                let chunk =
                                    // SAFETY: disjoint element ranges.
                                    unsafe { parts.slice(r.start, r.end - r.start) };
                                for v in chunk.iter_mut() {
                                    *v = v.max(0.0);
                                }
                            });
                        }
                    });
                }
                StepKind::Dense { w, first, m, k, n } => {
                    let lw = &self.weights.layers[*w];
                    let dst_is_a = !first_done || !cur_a;
                    let (dst, src) = if dst_is_a { (&mut *a, &*b) } else { (&mut *b, &*a) };
                    let (x_mu, x_aux): (&[f32], &[f32]) = if first_done {
                        (&src.mu[..step.in_len], &src.aux[..step.in_len])
                    } else {
                        // Eq. 13 / mean-only ignore the activation aux:
                        // the folded-away squared() pass
                        (x, x)
                    };
                    let (w_aux, b_var): (&[f32], Option<&[f32]>) = match (self.mode, *first) {
                        (PlanMode::Det, _) => (lw.w_mu.data(), None),
                        (PlanMode::Pfp, true) => (lw.w_var.data(), Some(lw.b_var.data())),
                        (PlanMode::Pfp, false) => (lw.w_e2.data(), Some(lw.b_var.data())),
                    };
                    let args = DenseSlices {
                        m: *m,
                        k: *k,
                        n: *n,
                        x_mu,
                        x_aux,
                        w_mu: lw.w_mu.data(),
                        w_aux,
                        b_mu: Some(lw.b_mu.data()),
                        b_var,
                    };
                    let out_mu = &mut dst.mu[..step.out_len];
                    let out_var = &mut dst.aux[..step.out_len];
                    if step.mean_prec.is_f32() && step.var_prec.is_f32() {
                        // all-f32 step: the pre-mixed-precision lowering,
                        // bit for bit
                        profiler.record(&step.label, step.op_type, || {
                            match (self.mode, *first) {
                                (PlanMode::Det, _) => dense_kernel_tiled_into::<MeanOnly>(
                                    pool, &args, &step.sched, step.epilogue, &step.tiles,
                                    out_mu, out_var,
                                ),
                                (PlanMode::Pfp, true) => dense_kernel_tiled_into::<FirstLayer>(
                                    pool, &args, &step.sched, step.epilogue, &step.tiles,
                                    out_mu, out_var,
                                ),
                                (PlanMode::Pfp, false) => dense_kernel_tiled_into::<JointEq12>(
                                    pool, &args, &step.sched, step.epilogue, &step.tiles,
                                    out_mu, out_var,
                                ),
                            }
                        });
                    } else {
                        // packed step: u16 weight operands widen to f32
                        // registers inside the kernel (accumulation stays
                        // f32), then the output activations round-trip
                        // through u16 storage per moment path
                        let pargs = PackedDenseSlices {
                            m: args.m,
                            k: args.k,
                            n: args.n,
                            x_mu: args.x_mu,
                            x_aux: args.x_aux,
                            w_mu: match &step.packed_wm {
                                Some(p) => PackedSlice::U16(step.mean_prec, p.as_slice()),
                                None => PackedSlice::F32(args.w_mu),
                            },
                            w_aux: match &step.packed_wa {
                                Some(p) => PackedSlice::U16(step.var_prec, p.as_slice()),
                                None => PackedSlice::F32(args.w_aux),
                            },
                            b_mu: args.b_mu,
                            b_var: args.b_var,
                        };
                        let be = simd::resolve(step.sched.isa);
                        let packed = &mut packed[..];
                        profiler.record(&step.label, step.op_type, || {
                            match (self.mode, *first) {
                                (PlanMode::Det, _) => dense_kernel_packed_tiled_into::<MeanOnly>(
                                    pool, &pargs, &step.sched, step.epilogue, &step.tiles,
                                    out_mu, out_var,
                                ),
                                (PlanMode::Pfp, true) => {
                                    dense_kernel_packed_tiled_into::<FirstLayer>(
                                        pool, &pargs, &step.sched, step.epilogue, &step.tiles,
                                        out_mu, out_var,
                                    )
                                }
                                (PlanMode::Pfp, false) => {
                                    dense_kernel_packed_tiled_into::<JointEq12>(
                                        pool, &pargs, &step.sched, step.epilogue, &step.tiles,
                                        out_mu, out_var,
                                    )
                                }
                            }
                            store_activations(be, step.mean_prec, out_mu, packed);
                            if self.mode == PlanMode::Pfp {
                                store_activations(be, step.var_prec, out_var, packed);
                            }
                        });
                    }
                    cur_a = dst_is_a;
                    first_done = true;
                }
                StepKind::Conv { w, first, shape, scatter } => {
                    let lw = &self.weights.layers[*w];
                    let dst_is_a = !first_done || !cur_a;
                    let (dst, src) = if dst_is_a { (&mut *a, &*b) } else { (&mut *b, &*a) };
                    let x_mu: &[f32] = if first_done { &src.mu[..step.in_len] } else { x };
                    // None = ignored-aux formulations (Eq. 13 / mean-only):
                    // the kernel aliases the mean patches instead
                    let x_aux: Option<&[f32]> = if self.mode == PlanMode::Det || *first {
                        None
                    } else {
                        Some(&src.aux[..step.in_len])
                    };
                    let (w_aux, b_var): (&[f32], Option<&[f32]>) = match (self.mode, *first) {
                        (PlanMode::Det, _) => (lw.w_mu.data(), None),
                        (PlanMode::Pfp, true) => (lw.w_var.data(), Some(lw.b_var.data())),
                        (PlanMode::Pfp, false) => (lw.w_e2.data(), Some(lw.b_var.data())),
                    };
                    let out_mu = &mut dst.mu[..step.out_len];
                    let out_var = &mut dst.aux[..step.out_len];
                    let scratch = &mut scratch[..];
                    if step.mean_prec.is_f32() && step.var_prec.is_f32() {
                        // all-f32 step: the pre-mixed-precision lowering,
                        // bit for bit
                        profiler.record(&step.label, step.op_type, || {
                            match (self.mode, *first) {
                                (PlanMode::Det, _) => conv_kernel_tiled_into::<MeanOnly>(
                                    pool,
                                    shape,
                                    x_mu,
                                    x_aux,
                                    lw.w_mu.data(),
                                    w_aux,
                                    Some(lw.b_mu.data()),
                                    b_var,
                                    &step.sched,
                                    step.epilogue,
                                    &step.tiles,
                                    scatter,
                                    scratch,
                                    out_mu,
                                    out_var,
                                ),
                                (PlanMode::Pfp, true) => conv_kernel_tiled_into::<FirstLayer>(
                                    pool,
                                    shape,
                                    x_mu,
                                    x_aux,
                                    lw.w_mu.data(),
                                    w_aux,
                                    Some(lw.b_mu.data()),
                                    b_var,
                                    &step.sched,
                                    step.epilogue,
                                    &step.tiles,
                                    scatter,
                                    scratch,
                                    out_mu,
                                    out_var,
                                ),
                                (PlanMode::Pfp, false) => conv_kernel_tiled_into::<JointEq12>(
                                    pool,
                                    shape,
                                    x_mu,
                                    x_aux,
                                    lw.w_mu.data(),
                                    w_aux,
                                    Some(lw.b_mu.data()),
                                    b_var,
                                    &step.sched,
                                    step.epilogue,
                                    &step.tiles,
                                    scatter,
                                    scratch,
                                    out_mu,
                                    out_var,
                                ),
                            }
                        });
                    } else {
                        // packed step: the fused im2col+dense phase widens
                        // the u16 weight tiles in registers; outputs then
                        // round-trip through u16 activation storage
                        let wm = match &step.packed_wm {
                            Some(p) => PackedSlice::U16(step.mean_prec, p.as_slice()),
                            None => PackedSlice::F32(lw.w_mu.data()),
                        };
                        let wa = match &step.packed_wa {
                            Some(p) => PackedSlice::U16(step.var_prec, p.as_slice()),
                            None => PackedSlice::F32(w_aux),
                        };
                        let be = simd::resolve(step.sched.isa);
                        let packed = &mut packed[..];
                        profiler.record(&step.label, step.op_type, || {
                            match (self.mode, *first) {
                                (PlanMode::Det, _) => conv_kernel_packed_tiled_into::<MeanOnly>(
                                    pool,
                                    shape,
                                    x_mu,
                                    x_aux,
                                    wm,
                                    wa,
                                    Some(lw.b_mu.data()),
                                    b_var,
                                    &step.sched,
                                    step.epilogue,
                                    &step.tiles,
                                    scatter,
                                    scratch,
                                    out_mu,
                                    out_var,
                                ),
                                (PlanMode::Pfp, true) => {
                                    conv_kernel_packed_tiled_into::<FirstLayer>(
                                        pool,
                                        shape,
                                        x_mu,
                                        x_aux,
                                        wm,
                                        wa,
                                        Some(lw.b_mu.data()),
                                        b_var,
                                        &step.sched,
                                        step.epilogue,
                                        &step.tiles,
                                        scatter,
                                        scratch,
                                        out_mu,
                                        out_var,
                                    )
                                }
                                (PlanMode::Pfp, false) => {
                                    conv_kernel_packed_tiled_into::<JointEq12>(
                                        pool,
                                        shape,
                                        x_mu,
                                        x_aux,
                                        wm,
                                        wa,
                                        Some(lw.b_mu.data()),
                                        b_var,
                                        &step.sched,
                                        step.epilogue,
                                        &step.tiles,
                                        scatter,
                                        scratch,
                                        out_mu,
                                        out_var,
                                    )
                                }
                            }
                            store_activations(be, step.mean_prec, out_mu, packed);
                            if self.mode == PlanMode::Pfp {
                                store_activations(be, step.var_prec, out_var, packed);
                            }
                        });
                    }
                    cur_a = dst_is_a;
                    first_done = true;
                }
                StepKind::Relu => {
                    let (dst, src) = if cur_a { (&mut *b, &*a) } else { (&mut *a, &*b) };
                    let mu_in = &src.mu[..step.in_len];
                    let var_in = &src.aux[..step.in_len];
                    let mu_out = &mut dst.mu[..step.out_len];
                    let e2_out = &mut dst.aux[..step.out_len];
                    profiler.record(&step.label, step.op_type, || {
                        pfp_relu_tiled_into(
                            pool, step.sched.isa, mu_in, var_in, &step.tiles, mu_out, e2_out,
                        )
                    });
                    cur_a = !cur_a;
                }
                StepKind::MaxPool { vectorized, n, c, h, w } => {
                    let (dst, src) = if cur_a { (&mut *b, &*a) } else { (&mut *a, &*b) };
                    let mu_in = &src.mu[..step.in_len];
                    let var_in = &src.aux[..step.in_len];
                    let mu_out = &mut dst.mu[..step.out_len];
                    let var_out = &mut dst.aux[..step.out_len];
                    profiler.record(&step.label, step.op_type, || {
                        if *vectorized {
                            pfp_maxpool2_tiled_into(
                                pool, step.sched.isa, mu_in, var_in, *n, *c, *h, *w,
                                &step.tiles, mu_out, var_out,
                            )
                        } else {
                            pfp_maxpool_generic_into(
                                mu_in, var_in, *n, *c, *h, *w, 2, 2, mu_out, var_out,
                            )
                        }
                    });
                    cur_a = !cur_a;
                }
                StepKind::MaxPoolDet { n, c, h, w } => {
                    let (dst, src) = if cur_a { (&mut *b, &*a) } else { (&mut *a, &*b) };
                    let mu_in = &src.mu[..step.in_len];
                    let mu_out = &mut dst.mu[..step.out_len];
                    profiler.record(&step.label, step.op_type, || {
                        det_maxpool2_tiled_into(
                            pool, mu_in, *n, *c, *h, *w, &step.tiles, mu_out,
                        )
                    });
                    cur_a = !cur_a;
                }
            }
        }

        let out: &BufPair = if cur_a { a } else { b };
        (&out.mu[..self.out_len], &out.aux[..self.out_len])
    }
}

fn convert_step(from: Rep, to: Rep, len: usize, at: &str) -> Step {
    Step {
        kind: StepKind::Convert { from, to },
        sched: Schedule::baseline(),
        tiles: Vec::new(),
        label: format!("Convert@{at}"),
        op_type: "convert",
        epilogue: Epilogue::None,
        in_len: len,
        out_len: len,
        mean_prec: Precision::F32,
        var_prec: Precision::F32,
        packed_wm: None,
        packed_wa: None,
    }
}

/// Resolve one compute step's storage precisions from its bound schedule
/// (which [`Schedules::layer_schedule`] already subjected to the
/// `--precision` override): the mean path carries the schedule's knob,
/// the variance path follows it unless [`Schedules::var_precision`]
/// splits the roles. Det plans have no variance path — it pins to f32 so
/// a det lowering never packs aux weights.
fn step_precisions(sched: &Schedule, schedules: &Schedules, pfp: bool) -> (Precision, Precision) {
    let mean_prec = sched.precision;
    let var_prec = if pfp {
        schedules.var_precision.unwrap_or(mean_prec)
    } else {
        Precision::F32
    };
    (mean_prec, var_prec)
}

/// Inter-layer activation storage at `prec`: narrow the f32 values into
/// the workspace's u16 staging buffer and widen back in place — after
/// this, `vals` holds exactly the values a `prec`-storage buffer would
/// hand the next consumer (the widening is exact), while downstream
/// kernels keep reading f32. No-op (and untouched buffers) for f32.
fn store_activations(be: simd::Backend, prec: Precision, vals: &mut [f32], bits: &mut [u16]) {
    if prec.is_f32() {
        return;
    }
    let bits = &mut bits[..vals.len()];
    simd::narrow_into(be, prec, vals, bits);
    simd::widen_into(be, prec, bits, vals);
}

/// PR 8 convert absorption: when the pending E2→Var conversion's input is
/// the output of a step that already fused the ReLU, upgrade that step's
/// epilogue to [`Epilogue::ReluToVar`] instead of emitting a standalone
/// `Convert@<layer>` step — the subtraction happens on the same cache-hot
/// tile as the ReLU moments. Returns whether the conversion was absorbed.
/// Converts whose producer is a pool step (LeNet's `Convert@Conv2d 2` /
/// `Convert@Dense 1`) are not absorbable and still lower to explicit
/// steps.
fn absorb_var_convert(steps: &mut [Step]) -> bool {
    match steps.last_mut() {
        Some(s)
            if matches!(s.kind, StepKind::Dense { .. } | StepKind::Conv { .. })
                && s.epilogue == Epilogue::Relu =>
        {
            s.epilogue = Epilogue::ReluToVar;
            true
        }
        _ => false,
    }
}

impl std::fmt::Debug for CompiledPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompiledPlan")
            .field("arch", &self.arch_name)
            .field("mode", &self.mode)
            .field("batch", &self.batch)
            .field("steps", &self.steps.len())
            .field("hwm", &self.hwm)
            .field("scratch", &self.scratch_len)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;
    use crate::tensor::Tensor;
    use crate::util::prop::Gen;

    fn input(arch: &Arch, batch: usize, seed: u64) -> Tensor {
        let mut g = Gen::new(seed);
        let n = batch * arch.input_len();
        Tensor::new(vec![batch, arch.input_len()], (0..n).map(|_| g.f32_in(0.0, 1.0)).collect())
            .unwrap()
    }

    fn compile_pfp(arch: &Arch, batch: usize) -> (CompiledPlan, Workspace) {
        let w = Arc::new(PosteriorWeights::synthetic(arch, 9));
        let plan =
            CompiledPlan::compile(arch, w, &Schedules::tuned(1), batch, PlanMode::Pfp).unwrap();
        let ws = plan.workspace();
        (plan, ws)
    }

    #[test]
    fn mlp_plan_has_no_conversions() {
        // MLP: dense out (Var) -> relu (wants Var) -> out (E2) -> dense
        // (wants E2): the representation contracts chain with zero
        // conversions — the plan must discover that statically.
        let (plan, _) = compile_pfp(&Arch::mlp(), 4);
        assert_eq!(plan.num_steps(), 5, "3 dense + 2 relu, no converts");
        assert!(plan.step_labels().iter().all(|(_, t)| *t != "convert"));
    }

    #[test]
    fn lenet_plan_inserts_labelled_conversions() {
        let (plan, _) = compile_pfp(&Arch::lenet(), 2);
        let labels = plan.step_labels();
        let converts: Vec<&str> = labels
            .iter()
            .filter(|(_, t)| *t == "convert")
            .map(|(l, _)| l.as_str())
            .collect();
        // relu(E2) -> pool(Var) twice, pool(Var) -> conv2(E2),
        // pool2(Var) -> dense3(E2)
        assert_eq!(
            converts,
            ["Convert@Max Pool 1", "Convert@Conv2d 2", "Convert@Max Pool 2", "Convert@Dense 1"]
        );
        // 5 compute + 4 relu + 2 pool + 4 converts, no flatten step
        assert_eq!(plan.num_steps(), 15);
    }

    #[test]
    fn execute_matches_shapes_and_is_finite() {
        for arch in [Arch::mlp(), Arch::lenet()] {
            let (plan, mut ws) = compile_pfp(&arch, 3);
            assert_eq!(plan.out_shape(), (3, 10));
            let x = input(&arch, 3, 1);
            let mut prof = Profiler::new(false);
            let (mu, var) = plan.execute(x.data(), &mut ws, &mut prof);
            assert_eq!(mu.len(), 30);
            assert_eq!(var.len(), 30);
            assert!(mu.iter().all(|v| v.is_finite()), "{}", arch.name);
            assert!(var.iter().all(|&v| v >= 0.0), "{}", arch.name);
        }
    }

    #[test]
    fn repeated_execute_is_bit_identical() {
        // workspace reuse must not leak state between calls
        let arch = Arch::lenet();
        let (plan, mut ws) = compile_pfp(&arch, 2);
        let x = input(&arch, 2, 5);
        let mut prof = Profiler::new(false);
        let (mu1, var1) = {
            let (m, v) = plan.execute(x.data(), &mut ws, &mut prof);
            (m.to_vec(), v.to_vec())
        };
        let (mu2, var2) = plan.execute(x.data(), &mut ws, &mut prof);
        assert_eq!(mu1.as_slice(), mu2);
        assert_eq!(var1.as_slice(), var2);
    }

    #[test]
    fn workspace_sized_at_high_water_mark() {
        let (plan, ws) = compile_pfp(&Arch::lenet(), 2);
        // LeNet b2 high-water mark: conv1 output 2*6*24*24 = 6912 floats
        assert_eq!(ws.capacity(), 6912);
        assert!(ws.scratch_capacity() > 0, "conv net needs im2col scratch");
        // the input is read from the caller's slice, not the workspace:
        // the MLP's high-water mark is its widest *hidden* layer
        let (mlp_plan, mlp_ws) = compile_pfp(&Arch::mlp(), 2);
        assert_eq!(mlp_ws.capacity(), 2 * 100);
        assert_eq!(mlp_ws.scratch_capacity(), 0, "dense net needs no scratch");
        assert_eq!(mlp_plan.out_shape(), (2, 10));
        let _ = plan;
    }

    #[test]
    fn det_mode_matches_relu_clamp_semantics() {
        // det plan output must be finite and reproducible
        let arch = Arch::mlp();
        let w = Arc::new(PosteriorWeights::synthetic(&arch, 3));
        let plan = CompiledPlan::compile(&arch, w, &Schedules::tuned(1), 2, PlanMode::Det)
            .unwrap();
        let mut ws = plan.workspace();
        let x = input(&arch, 2, 2);
        let mut prof = Profiler::new(false);
        let (mu, _) = plan.execute(x.data(), &mut ws, &mut prof);
        assert!(mu.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn plan_threads_partitions_steps_at_plan_time() {
        let arch = Arch::lenet();
        let w = Arc::new(PosteriorWeights::synthetic(&arch, 9));
        let serial = CompiledPlan::compile(
            &arch,
            Arc::clone(&w),
            &Schedules::tuned(1),
            2,
            PlanMode::Pfp,
        )
        .unwrap();
        assert_eq!(serial.num_parallel_steps(), 0, "tuned(1) lowers serial");
        let par = CompiledPlan::compile(
            &arch,
            Arc::clone(&w),
            &Schedules::tuned(1).with_plan_threads(4),
            2,
            PlanMode::Pfp,
        )
        .unwrap();
        // every conv (patch rows), dense (batch rows), relu (elements)
        // and vectorized pool (planes) step with >1 unit gets a partition
        assert!(
            par.num_parallel_steps() >= 11,
            "only {} of {} steps partitioned",
            par.num_parallel_steps(),
            par.num_steps()
        );
        // schedules carrying threads themselves also partition (no
        // plan_threads override needed)
        let from_sched =
            CompiledPlan::compile(&arch, w, &Schedules::tuned(3), 2, PlanMode::Pfp).unwrap();
        assert!(from_sched.num_parallel_steps() >= 5, "dense/conv steps partition");
    }

    #[test]
    fn parallel_execute_bit_identical_to_serial() {
        for arch in [Arch::mlp(), Arch::lenet()] {
            let w = Arc::new(PosteriorWeights::synthetic(&arch, 10));
            let x = input(&arch, 4, 21);
            let mut prof = Profiler::new(false);
            let serial = CompiledPlan::compile(
                &arch,
                Arc::clone(&w),
                &Schedules::tuned(1),
                4,
                PlanMode::Pfp,
            )
            .unwrap();
            let mut ws = serial.workspace();
            let (want_mu, want_var) = {
                let (m, v) = serial.execute(x.data(), &mut ws, &mut prof);
                (m.to_vec(), v.to_vec())
            };
            for t in [2usize, 3, 8] {
                let par = CompiledPlan::compile(
                    &arch,
                    Arc::clone(&w),
                    &Schedules::tuned(1).with_plan_threads(t),
                    4,
                    PlanMode::Pfp,
                )
                .unwrap();
                let mut ws = par.workspace();
                let (mu, var) = par.execute(x.data(), &mut ws, &mut prof);
                assert_eq!(want_mu.as_slice(), mu, "{} t={t} mu", arch.name);
                assert_eq!(want_var.as_slice(), var, "{} t={t} var", arch.name);
            }
        }
    }

    fn compile_pfp_fused(arch: &Arch, batch: usize) -> (CompiledPlan, Workspace) {
        use crate::model::FusePolicy;
        let w = Arc::new(PosteriorWeights::synthetic(arch, 9));
        let plan = CompiledPlan::compile(
            arch,
            w,
            &Schedules::tuned(1).with_fuse(FusePolicy::On),
            batch,
            PlanMode::Pfp,
        )
        .unwrap();
        let ws = plan.workspace();
        (plan, ws)
    }

    #[test]
    fn fused_mlp_absorbs_every_relu() {
        // MLP: dense -> relu -> dense -> relu -> dense. Both ReLUs follow
        // a dense producer, so fusion leaves only the 3 compute steps.
        let (plan, _) = compile_pfp_fused(&Arch::mlp(), 4);
        assert_eq!(plan.num_steps(), 3, "3 fused dense steps, nothing else");
        assert_eq!(plan.num_fused_steps(), 2, "classifier head has no relu");
        assert!(plan.step_labels().iter().all(|(_, t)| *t == "dense"));
    }

    #[test]
    fn fused_lenet_absorbs_relu_and_adjacent_converts() {
        // Each conv's relu + the E2->Var convert feeding the pool fold
        // into the conv step (ReluToVar); each hidden dense's relu folds
        // as Relu (next dense wants E2, so no convert exists). The two
        // converts after pool steps have no fusable producer and stay.
        let (plan, _) = compile_pfp_fused(&Arch::lenet(), 2);
        let labels = plan.step_labels();
        assert!(
            labels.iter().all(|(_, t)| *t != "relu"),
            "no standalone relu after a dense/conv producer: {labels:?}"
        );
        let converts: Vec<&str> = labels
            .iter()
            .filter(|(_, t)| *t == "convert")
            .map(|(l, _)| l.as_str())
            .collect();
        assert_eq!(converts, ["Convert@Conv2d 2", "Convert@Dense 1"]);
        // 5 compute (4 fused) + 2 pool + 2 post-pool converts
        assert_eq!(plan.num_steps(), 9);
        assert_eq!(plan.num_fused_steps(), 4);
        // workloads advertise the fused epilogues for the tuner
        let eps: Vec<Epilogue> =
            plan.dense_workloads().iter().map(|w| w.ep).collect();
        use Epilogue::*;
        assert_eq!(eps, [ReluToVar, ReluToVar, Relu, Relu, None]);
    }

    #[test]
    fn auto_policy_defers_to_schedule_knob() {
        use crate::model::FusePolicy;
        let arch = Arch::mlp();
        let w = Arc::new(PosteriorWeights::synthetic(&arch, 9));
        // stock schedules carry fuse: false -> Auto lowers unfused
        let auto = CompiledPlan::compile(
            &arch,
            Arc::clone(&w),
            &Schedules::tuned(1),
            2,
            PlanMode::Pfp,
        )
        .unwrap();
        assert_eq!(auto.num_fused_steps(), 0, "Auto + stock knobs = unfused");
        // a per-layer schedule with the tuner-searched knob on fuses just
        // that layer
        let knob = CompiledPlan::compile(
            &arch,
            Arc::clone(&w),
            &Schedules::tuned(1)
                .with_layer_schedule(0, Schedule::tuned(1).with_fuse(true)),
            2,
            PlanMode::Pfp,
        )
        .unwrap();
        assert_eq!(knob.num_fused_steps(), 1, "only the knobbed layer fuses");
        // Off overrides even explicit knobs
        let off = CompiledPlan::compile(
            &arch,
            w,
            &Schedules::tuned(1)
                .with_layer_schedule(0, Schedule::tuned(1).with_fuse(true))
                .with_fuse(FusePolicy::Off),
            2,
            PlanMode::Pfp,
        )
        .unwrap();
        assert_eq!(off.num_fused_steps(), 0);
    }

    #[test]
    fn fused_execute_bit_identical_to_unfused() {
        // The correctness contract: within one ISA, the fused epilogue
        // runs the same position-independent elementwise kernels on the
        // same values, so fused == unfused bit for bit — serial and at
        // every plan-thread count.
        use crate::model::FusePolicy;
        for arch in [Arch::mlp(), Arch::lenet()] {
            let w = Arc::new(PosteriorWeights::synthetic(&arch, 17));
            let x = input(&arch, 4, 23);
            let mut prof = Profiler::new(false);
            let unfused = CompiledPlan::compile(
                &arch,
                Arc::clone(&w),
                &Schedules::tuned(1),
                4,
                PlanMode::Pfp,
            )
            .unwrap();
            let mut ws = unfused.workspace();
            let (want_mu, want_var) = {
                let (m, v) = unfused.execute(x.data(), &mut ws, &mut prof);
                (m.to_vec(), v.to_vec())
            };
            for t in [1usize, 2, 4] {
                let fused = CompiledPlan::compile(
                    &arch,
                    Arc::clone(&w),
                    &Schedules::tuned(1)
                        .with_fuse(FusePolicy::On)
                        .with_plan_threads(t),
                    4,
                    PlanMode::Pfp,
                )
                .unwrap();
                assert!(fused.num_fused_steps() > 0, "{}", arch.name);
                let mut ws = fused.workspace();
                let (mu, var) = fused.execute(x.data(), &mut ws, &mut prof);
                assert_eq!(want_mu.as_slice(), mu, "{} t={t} mu", arch.name);
                assert_eq!(want_var.as_slice(), var, "{} t={t} var", arch.name);
            }
        }
    }

    #[test]
    fn fused_profiling_attributes_absorbed_work_to_producer_rows() {
        // the fused-step accounting contract (see profiling/mod.rs):
        // absorbed relu/convert work files under the producing layer's
        // Table-4 row with the compute op type; only the standalone
        // post-pool converts keep "convert" samples
        let arch = Arch::lenet();
        let (plan, mut ws) = compile_pfp_fused(&arch, 2);
        let x = input(&arch, 2, 13);
        let mut prof = Profiler::new(true);
        prof.begin_pass();
        let _ = plan.execute(x.data(), &mut ws, &mut prof);
        let profile = prof.take();
        assert_eq!(profile.samples.len(), plan.num_steps(), "one sample per step");
        assert!(
            profile.samples.iter().all(|s| s.op_type != "relu"),
            "absorbed relus must not record their own samples"
        );
        let convert_rows: Vec<&str> = profile
            .samples
            .iter()
            .filter(|s| s.op_type == "convert")
            .map(|s| s.label.as_str())
            .collect();
        assert_eq!(convert_rows, ["Convert@Conv2d 2", "Convert@Dense 1"]);
        // every compute layer still owns exactly one Table-4 row, under
        // its own label and compute op type
        let compute: Vec<(&str, &str)> = profile
            .samples
            .iter()
            .filter(|s| s.op_type == "conv2d" || s.op_type == "dense")
            .map(|s| (s.label.as_str(), s.op_type.as_str()))
            .collect();
        assert_eq!(compute.len(), 5, "5 compute layers, one row each");
        // Fig. 6 aggregate: the convert share now covers only the two
        // standalone steps; no relu row exists at all
        let types = profile.by_op_type();
        assert!(types.iter().any(|r| r.label == "convert"));
        assert!(types.iter().all(|r| r.label != "relu"));
    }

    #[test]
    fn fused_workspace_high_water_mark_unchanged() {
        // absorbed ops are same-length elementwise passes: recomputing the
        // hwm over the shorter step list lands on the same arena size
        for arch in [Arch::mlp(), Arch::lenet()] {
            let (_, unfused_ws) = compile_pfp(&arch, 2);
            let (_, fused_ws) = compile_pfp_fused(&arch, 2);
            assert_eq!(unfused_ws.capacity(), fused_ws.capacity(), "{}", arch.name);
            assert_eq!(
                unfused_ws.scratch_capacity(),
                fused_ws.scratch_capacity(),
                "{}",
                arch.name
            );
        }
    }

    #[test]
    fn stock_f32_plans_carry_no_packed_state() {
        // the back-compat contract: stock schedules default to f32, so
        // existing plans lower with zero packed weights, zero staging
        // buffer, and take the pre-mixed-precision execute path bit for
        // bit (covered by plan_forward_matches_interpreter_bitwise)
        for arch in [Arch::mlp(), Arch::lenet()] {
            let (plan, ws) = compile_pfp(&arch, 2);
            assert_eq!(plan.num_packed_steps(), 0, "{}", arch.name);
            assert_eq!(plan.packed_tensors(), 0);
            assert_eq!(ws.packed_capacity(), 0, "f32 plans allocate no staging");
        }
    }

    #[test]
    fn packed_plans_execute_finite_and_deterministic() {
        use crate::model::FusePolicy;
        for arch in [Arch::mlp(), Arch::lenet()] {
            let w = Arc::new(PosteriorWeights::synthetic(&arch, 19));
            let x = input(&arch, 3, 29);
            let mut prof = Profiler::new(false);
            for p in [Precision::F16, Precision::Bf16] {
                let sched = Schedules::tuned(1).with_precision_override(Some(p));
                let plan =
                    CompiledPlan::compile(&arch, Arc::clone(&w), &sched, 3, PlanMode::Pfp)
                        .unwrap();
                assert_eq!(plan.num_packed_steps(), arch.compute_layers().len());
                assert_eq!(
                    plan.packed_tensors(),
                    2 * arch.compute_layers().len(),
                    "mu + aux weights pack per compute step"
                );
                let mut ws = plan.workspace();
                assert!(ws.packed_capacity() > 0, "staging buffer sized at compile");
                let (mu1, var1) = {
                    let (m, v) = plan.execute(x.data(), &mut ws, &mut prof);
                    (m.to_vec(), v.to_vec())
                };
                assert!(mu1.iter().all(|v| v.is_finite()), "{} {p}", arch.name);
                assert!(var1.iter().all(|&v| v >= 0.0 && v.is_finite()));
                // workspace reuse leaks no state across calls
                let (mu2, var2) = plan.execute(x.data(), &mut ws, &mut prof);
                assert_eq!(mu1.as_slice(), mu2);
                assert_eq!(var1.as_slice(), var2);
                // row-partitioned packed steps stay bit-identical to
                // serial — the determinism guarantee extends to packed
                for t in [2usize, 4] {
                    let par = CompiledPlan::compile(
                        &arch,
                        Arc::clone(&w),
                        &sched.clone().with_plan_threads(t),
                        3,
                        PlanMode::Pfp,
                    )
                    .unwrap();
                    let mut pws = par.workspace();
                    let (pm, pv) = par.execute(x.data(), &mut pws, &mut prof);
                    assert_eq!(mu1.as_slice(), pm, "{} {p} t={t} mu", arch.name);
                    assert_eq!(var1.as_slice(), pv, "{} {p} t={t} var", arch.name);
                }
                // fused + packed compose: the epilogue runs in-register
                // first, then the (post-relu) outputs hit u16 storage
                let fused = CompiledPlan::compile(
                    &arch,
                    Arc::clone(&w),
                    &sched.clone().with_fuse(FusePolicy::On),
                    3,
                    PlanMode::Pfp,
                )
                .unwrap();
                assert!(fused.num_fused_steps() > 0);
                assert!(fused.num_packed_steps() > 0);
                let mut fws = fused.workspace();
                let (fm, fv) = fused.execute(x.data(), &mut fws, &mut prof);
                assert!(fm.iter().all(|v| v.is_finite()));
                assert!(fv.iter().all(|&v| v >= 0.0 && v.is_finite()));
            }
        }
    }

    #[test]
    fn var_precision_splits_moment_roles() {
        // mean and variance storage precision are independently settable;
        // det plans have no variance path and never pack aux weights
        let arch = Arch::mlp();
        let w = Arc::new(PosteriorWeights::synthetic(&arch, 23));
        let x = input(&arch, 2, 31);
        let mut prof = Profiler::new(false);
        // mean f32, variance bf16: only the aux tensors pack
        let s = Schedules::tuned(1).with_var_precision(Some(Precision::Bf16));
        let plan = CompiledPlan::compile(&arch, Arc::clone(&w), &s, 2, PlanMode::Pfp).unwrap();
        assert_eq!(plan.num_packed_steps(), 3);
        assert_eq!(plan.packed_tensors(), 3, "one aux tensor per dense layer");
        let mut ws = plan.workspace();
        let (mu, var) = plan.execute(x.data(), &mut ws, &mut prof);
        assert!(mu.iter().all(|v| v.is_finite()));
        assert!(var.iter().all(|&v| v >= 0.0));
        // mean bf16, variance pinned back to f32: only mu tensors pack
        let s = Schedules::tuned(1)
            .with_precision_override(Some(Precision::Bf16))
            .with_var_precision(Some(Precision::F32));
        let plan = CompiledPlan::compile(&arch, Arc::clone(&w), &s, 2, PlanMode::Pfp).unwrap();
        assert_eq!(plan.packed_tensors(), 3, "one mu tensor per dense layer");
        // det mode: f16 means, no aux packing at all
        let s = Schedules::tuned(1).with_precision_override(Some(Precision::F16));
        let det = CompiledPlan::compile(&arch, Arc::clone(&w), &s, 2, PlanMode::Det).unwrap();
        assert_eq!(det.packed_tensors(), 3, "det packs only mu");
        let mut dws = det.workspace();
        let (dmu, _) = det.execute(x.data(), &mut dws, &mut prof);
        assert!(dmu.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn dense_workloads_report_actual_shapes() {
        let (plan, _) = compile_pfp(&Arch::lenet(), 10);
        let wl = plan.dense_workloads();
        assert_eq!(wl.len(), 5);
        // conv1: rows = 10*24*24, k = 1*5*5, n = 6
        assert_eq!((wl[0].op, wl[0].m, wl[0].k, wl[0].n), ("conv", 5760, 25, 6));
        // first dense after flatten: 10 x 256 -> 120
        assert_eq!((wl[2].op, wl[2].m, wl[2].k, wl[2].n), ("dense", 10, 256, 120));
        assert_eq!(wl[4].n, 10, "classifier head");
        assert_eq!(wl[1].compute_idx, 1);
    }

    #[test]
    fn batch_mismatch_panics() {
        let (plan, mut ws) = compile_pfp(&Arch::mlp(), 2);
        let x = input(&Arch::mlp(), 3, 0);
        let mut prof = Profiler::new(false);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = plan.execute(x.data(), &mut ws, &mut prof);
        }));
        assert!(r.is_err());
    }

    #[test]
    fn compile_rejects_weight_shape_mismatch() {
        let arch = Arch::mlp();
        let w = Arc::new(PosteriorWeights::synthetic(&Arch::lenet(), 1));
        assert!(CompiledPlan::compile(&arch, w, &Schedules::tuned(1), 1, PlanMode::Pfp)
            .is_err());
    }
}
