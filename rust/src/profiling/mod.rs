//! Per-operator profiling (Table 4 / Fig. 6).
//!
//! The native executor reports each layer's wall-clock into a [`Profiler`];
//! [`Profile::by_layer`] reproduces Table 4's per-layer rows and
//! [`Profile::by_op_type`] Fig. 6's per-operator-type shares (including
//! the representation-conversion overhead the paper files under
//! "tooling").
//!
//! ## Fused-step accounting (PR 8)
//!
//! Epilogue fusion collapses a `dense/conv → pfp_relu (→ Convert)` chain
//! into a single plan step, so the absorbed ReLU/convert work no longer
//! records its own sample. The accounting contract:
//!
//! * a fused step records **once**, under the producing layer's Table-4
//!   label (`"Dense 1"`, `"Conv2d 1"`, …) with the compute op type
//!   (`"dense"` / `"conv2d"`) — the absorbed elementwise time is folded
//!   into the producer's row, never dropped, so `total_per_pass_ms` and
//!   the per-layer sums stay comparable pre/post fusion (the same layer's
//!   work moves between its own rows, it does not leave the layer);
//! * the aggregate `"relu"` and `"convert"` rows of [`by_op_type`]
//!   (Fig. 6) therefore shrink to the **standalone** steps that remain
//!   (e.g. the post-pool `Convert@<layer>` steps, which are never
//!   fusable) — a fused plan legitimately reports a smaller conversion-
//!   overhead share, because that overhead genuinely no longer exists as
//!   separate memory passes;
//! * `Convert@<layer>` rows for absorbed conversions disappear from
//!   Table 4 rather than reporting 0 ms, mirroring the compiled plan's
//!   actual step list ([`by_layer`] reads what ran, not the pre-fusion
//!   lowering).
//!
//! [`by_layer`]: Profile::by_layer
//! [`by_op_type`]: Profile::by_op_type

use std::time::{Duration, Instant};

/// One timed region.
#[derive(Clone, Debug)]
pub struct Sample {
    pub label: String,
    pub op_type: String,
    pub dur: Duration,
}

/// Collects per-layer samples across one or more forward passes.
#[derive(Default, Debug)]
pub struct Profiler {
    samples: Vec<Sample>,
    enabled: bool,
    pass_count: usize,
}

impl Profiler {
    pub fn new(enabled: bool) -> Self {
        Self { samples: Vec::new(), enabled, pass_count: 0 }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn begin_pass(&mut self) {
        if self.enabled {
            self.pass_count += 1;
        }
    }

    /// Time `f`, filing the duration under (label, op_type).
    pub fn record<T>(&mut self, label: &str, op_type: &str, f: impl FnOnce() -> T) -> T {
        if !self.enabled {
            return f();
        }
        let t = Instant::now();
        let out = f();
        self.samples.push(Sample {
            label: label.to_string(),
            op_type: op_type.to_string(),
            dur: t.elapsed(),
        });
        out
    }

    pub fn take(&mut self) -> Profile {
        Profile {
            samples: std::mem::take(&mut self.samples),
            passes: std::mem::replace(&mut self.pass_count, 0).max(1),
        }
    }
}

/// Aggregated profile over `passes` forward passes.
#[derive(Clone, Debug)]
pub struct Profile {
    pub samples: Vec<Sample>,
    pub passes: usize,
}

#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub total_ms: f64,
    /// mean per forward pass
    pub per_pass_ms: f64,
    pub fraction: f64,
}

impl Profile {
    fn aggregate(&self, key: impl Fn(&Sample) -> String) -> Vec<Row> {
        let mut order: Vec<String> = Vec::new();
        let mut totals: std::collections::HashMap<String, f64> = Default::default();
        for s in &self.samples {
            let k = key(s);
            if !totals.contains_key(&k) {
                order.push(k.clone());
            }
            *totals.entry(k).or_insert(0.0) += s.dur.as_secs_f64() * 1e3;
        }
        let grand: f64 = totals.values().sum();
        let mut rows: Vec<Row> = order
            .into_iter()
            .map(|k| {
                let t = totals[&k];
                Row {
                    label: k,
                    total_ms: t,
                    per_pass_ms: t / self.passes as f64,
                    fraction: if grand > 0.0 { t / grand } else { 0.0 },
                }
            })
            .collect();
        rows.sort_by(|a, b| b.total_ms.partial_cmp(&a.total_ms).unwrap());
        rows
    }

    /// Table 4: per-layer rows ("Dense 1", "ReLU 2", ...), sorted by cost.
    pub fn by_layer(&self) -> Vec<Row> {
        self.aggregate(|s| s.label.clone())
    }

    /// Fig. 6: per-operator-type shares ("dense", "relu", ...).
    pub fn by_op_type(&self) -> Vec<Row> {
        self.aggregate(|s| s.op_type.clone())
    }

    /// Total wall-clock per forward pass (ms).
    pub fn total_per_pass_ms(&self) -> f64 {
        self.samples.iter().map(|s| s.dur.as_secs_f64() * 1e3).sum::<f64>()
            / self.passes as f64
    }

    /// Render a Table-4 style report.
    pub fn render(&self, title: &str) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(out, "== {title} (avg over {} passes) ==", self.passes);
        let _ = writeln!(out, "{:<16} {:>12} {:>9}", "layer", "latency", "fraction");
        for r in self.by_layer() {
            let _ = writeln!(
                out,
                "{:<16} {:>10.3}ms {:>8.1}%",
                r.label,
                r.per_pass_ms,
                r.fraction * 100.0
            );
        }
        let _ = writeln!(out, "{:<16} {:>10.3}ms {:>8}", "Entire Network",
                         self.total_per_pass_ms(), "100.0%");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_profiler_is_passthrough() {
        let mut p = Profiler::new(false);
        let v = p.record("Dense 1", "dense", || 42);
        assert_eq!(v, 42);
        assert!(p.take().samples.is_empty());
    }

    #[test]
    fn aggregates_by_layer_and_type() {
        let mut p = Profiler::new(true);
        p.begin_pass();
        p.record("Dense 1", "dense", || std::thread::sleep(Duration::from_millis(2)));
        p.record("Dense 2", "dense", || std::thread::sleep(Duration::from_millis(1)));
        p.record("ReLU 1", "relu", || std::thread::sleep(Duration::from_millis(1)));
        let prof = p.take();
        let layers = prof.by_layer();
        assert_eq!(layers.len(), 3);
        // rows are sorted by cost descending (exact order depends on
        // scheduler noise; assert the invariant, not the specific labels)
        for w in layers.windows(2) {
            assert!(w[0].total_ms >= w[1].total_ms);
        }
        let types = prof.by_op_type();
        assert_eq!(types.len(), 2);
        assert_eq!(types[0].label, "dense");
        let frac_sum: f64 = types.iter().map(|r| r.fraction).sum();
        assert!((frac_sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn per_pass_normalisation() {
        let mut p = Profiler::new(true);
        for _ in 0..4 {
            p.begin_pass();
            p.record("Dense 1", "dense", || std::thread::sleep(Duration::from_millis(1)));
        }
        let prof = p.take();
        assert_eq!(prof.passes, 4);
        let row = &prof.by_layer()[0];
        assert!(row.per_pass_ms < row.total_ms);
    }
}
