//! Connection-scaling bench for the event-driven front end.
//!
//! The reactor's operational claim is that connections are cheap: a small
//! fixed set of IO threads (here 4) owns every socket, so thousands of
//! idle connections cost file descriptors and a few KB of buffers — not
//! two OS threads each — while hundreds of active pipelined connections
//! share the same event loops at low tail latency. This bench measures
//! that claim directly, with a synthetic stub backend (no artifacts):
//!
//! * **idle fleet** — open ~5000 connections (scaled down if the fd
//!   rlimit cannot be raised far enough), roundtrip a ping on each, and
//!   record process thread count + RSS growth: both must stay flat;
//! * **active fleet** — 200 pipelined connections driven by 8 client
//!   threads, a fixed request count each, per-request latency recorded
//!   by id; asserts zero errors and reports p50/p99/p99.9;
//! * emits `BENCH_conn.json` (committed into `bench/` by CI's bench-perf
//!   job as part of the perf trajectory).
//!
//! Fast mode (`PFP_BENCH_FAST=1`): 256 idle / 16 active connections.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use pfp::coordinator::{protocol, Backend, BatcherConfig, Server, ServerConfig, Service};
use pfp::tensor::Tensor;
use pfp::util::json::Json;
use pfp::util::stats;

/// Raise the soft fd limit toward `want`; returns the resulting soft
/// limit. Best effort — the bench scales its idle fleet to whatever it
/// gets.
#[cfg(unix)]
fn raise_nofile(want: u64) -> u64 {
    #[repr(C)]
    struct Rlimit {
        cur: u64,
        max: u64,
    }
    #[cfg(target_os = "linux")]
    const RLIMIT_NOFILE: i32 = 7;
    #[cfg(not(target_os = "linux"))]
    const RLIMIT_NOFILE: i32 = 8;
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }
    let mut r = Rlimit { cur: 0, max: 0 };
    // SAFETY: getrlimit writes through a valid, properly aligned pointer
    // to a #[repr(C)] struct matching the libc layout (rlim_t is u64 on
    // every supported unix); the return value is checked.
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut r) } != 0 {
        return 1024; // conservative guess; the bench scales down
    }
    if r.cur >= want {
        return r.cur;
    }
    // try the target, then macOS's OPEN_MAX fallback, capped at the hard
    // limit in both cases
    for cur in [want.min(r.max), 10240.min(r.max)] {
        let attempt = Rlimit { cur, max: r.max };
        // SAFETY: setrlimit reads through a valid pointer to the same
        // #[repr(C)] struct; cur <= max so the call is well-formed, and
        // the return value is checked (failure falls through).
        if unsafe { setrlimit(RLIMIT_NOFILE, &attempt) } == 0 {
            return cur;
        }
    }
    r.cur
}

#[cfg(not(unix))]
fn raise_nofile(_want: u64) -> u64 {
    1024
}

/// Resident set size in KB from /proc/self/status (Linux); None elsewhere.
fn rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmRSS:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// OS threads in this process (Linux); None elsewhere.
fn process_threads() -> Option<usize> {
    std::fs::read_dir("/proc/self/task").ok().map(|d| d.count())
}

/// Stub backend: fixed moments, no compute — the bench isolates the
/// connection layer, not the forward pass.
struct StubBackend;

impl Backend for StubBackend {
    fn infer(&mut self, x: &Tensor) -> pfp::Result<(Tensor, Tensor)> {
        let b = x.dim(0);
        Ok((Tensor::full(vec![b, 4], 0.5), Tensor::full(vec![b, 4], 1e-3)))
    }

    fn name(&self) -> String {
        "stub".into()
    }
}

/// One ping roundtrip on a bare (un-cloned) stream: a connection costs
/// exactly two fds here — the client socket and the server's accepted
/// socket.
fn ping(stream: &TcpStream) -> bool {
    if (&*stream).write_all(b"{\"cmd\":\"ping\"}\n").is_err() {
        return false;
    }
    let mut buf = [0u8; 256];
    let mut seen = Vec::new();
    loop {
        match (&*stream).read(&mut buf) {
            Ok(0) | Err(_) => return false,
            Ok(n) => {
                seen.extend_from_slice(&buf[..n]);
                if seen.contains(&b'\n') {
                    return std::str::from_utf8(&seen)
                        .map(|s| s.contains("pong"))
                        .unwrap_or(false);
                }
            }
        }
    }
}

/// Drive one pipelined connection: `n_reqs` requests with up to `window`
/// in flight, per-request latency matched by response id. Returns
/// (latencies_us, errors).
fn drive_conn(addr: SocketAddr, n_reqs: usize, window: usize) -> (Vec<f64>, usize) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut wire = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    writeln!(wire, r#"{{"cmd":"hello","pipeline":true}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"hello\":true"), "handshake failed: {line}");

    let input = [0.5f32; 4];
    let mut sent_at: HashMap<u64, Instant> = HashMap::new();
    let mut latencies = Vec::with_capacity(n_reqs);
    let mut errors = 0usize;
    let (mut sent, mut received) = (0u64, 0usize);
    while received < n_reqs {
        while (sent as usize) < n_reqs && sent_at.len() < window {
            sent_at.insert(sent, Instant::now());
            writeln!(wire, "{}", protocol::request_json(sent, "stub", &input)).unwrap();
            sent += 1;
        }
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = protocol::Response::parse(line.trim()).unwrap();
        if let Some(t0) = sent_at.remove(&resp.id) {
            latencies.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        if resp.result.is_err() {
            errors += 1;
        }
        received += 1;
    }
    (latencies, errors)
}

fn main() {
    let fast = std::env::var("PFP_BENCH_FAST").as_deref() == Ok("1");
    let idle_target: usize = if fast { 256 } else { 5000 };
    let active: usize = if fast { 16 } else { 200 };
    let reqs_per_conn: usize = if fast { 30 } else { 50 };
    let drivers: usize = 8;
    let window: usize = 4;
    let io_threads: usize = 4;

    // 2 fds per idle conn (client + accepted) + 3 per active conn (the
    // driver clones its stream) + headroom for the process itself
    let want = (2 * idle_target + 3 * active + 128) as u64;
    let got = raise_nofile(want);
    let idle = if got >= want {
        idle_target
    } else {
        let spare = (got as usize).saturating_sub(3 * active + 128);
        let scaled = (spare / 2).min(idle_target);
        println!(
            "fd limit {got} < {want}: scaling idle fleet {idle_target} -> {scaled}"
        );
        scaled
    };

    let mut cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        pipeline_depth: 32,
        io_threads,
        max_connections: idle + active + 8,
        pool_threads: 2,
        ..Default::default()
    };
    cfg.batcher = BatcherConfig {
        max_batch: 64,
        max_wait: Duration::from_millis(1),
        capacity: 8192,
    };
    let mut svc = Service::new(cfg);
    svc.register("stub", 4, Box::new(StubBackend));
    let svc = Arc::new(svc);
    let server = Server::bind(svc.clone()).unwrap();
    let addr = server.addr;
    let run_handle = std::thread::spawn(move || server.run());

    // warm one connection so every IO thread and lane is up, then baseline
    let admin = TcpStream::connect(addr).unwrap();
    admin.set_nodelay(true).unwrap();
    assert!(ping(&admin), "warm-up ping failed");
    let threads_baseline = process_threads();
    let rss_baseline = rss_kb();

    // ---- idle fleet -------------------------------------------------------
    let t0 = Instant::now();
    let mut idle_conns = Vec::with_capacity(idle);
    for i in 0..idle {
        let s = TcpStream::connect(addr)
            .unwrap_or_else(|e| panic!("idle conn {i}/{idle} failed: {e}"));
        s.set_nodelay(true).unwrap();
        idle_conns.push(s);
    }
    for (i, s) in idle_conns.iter().enumerate() {
        assert!(ping(s), "idle conn {i} not serviced");
    }
    let idle_setup = t0.elapsed();
    let threads_idle = process_threads();
    let rss_idle = rss_kb();
    if let (Some(b), Some(a)) = (threads_baseline, threads_idle) {
        assert!(
            a.saturating_sub(b) < 16,
            "{idle} idle conns grew threads {b} -> {a}: per-connection threads are back"
        );
    }
    if let (Some(b), Some(a)) = (rss_baseline, rss_idle) {
        assert!(
            a.saturating_sub(b) < 100 * 1024,
            "{idle} idle conns grew RSS {b}KB -> {a}KB"
        );
    }

    // ---- active fleet -----------------------------------------------------
    let t1 = Instant::now();
    let mut handles = Vec::new();
    for d in 0..drivers {
        let mine = (active + drivers - 1 - d) / drivers; // spread remainder
        handles.push(std::thread::spawn(move || {
            let mut lat = Vec::new();
            let mut errs = 0usize;
            for _ in 0..mine {
                let (l, e) = drive_conn(addr, reqs_per_conn, window);
                lat.extend(l);
                errs += e;
            }
            (lat, errs)
        }));
    }
    let mut latencies = Vec::with_capacity(active * reqs_per_conn);
    let mut errors = 0usize;
    for h in handles {
        let (l, e) = h.join().expect("driver thread panicked");
        latencies.extend(l);
        errors += e;
    }
    let active_wall = t1.elapsed().as_secs_f64();
    let total_reqs = latencies.len();
    assert_eq!(errors, 0, "active fleet saw {errors} error responses");
    assert_eq!(total_reqs, active * reqs_per_conn);

    let threads_after = process_threads();
    let rss_after = rss_kb();
    if let (Some(b), Some(a)) = (threads_baseline, threads_after) {
        assert!(
            a.saturating_sub(b) < 16,
            "active fleet grew threads {b} -> {a}"
        );
    }

    let p50 = stats::percentile(&latencies, 50.0);
    let p99 = stats::percentile(&latencies, 99.0);
    let p999 = stats::percentile(&latencies, 99.9);
    let rps = total_reqs as f64 / active_wall;

    println!(
        "idle fleet:   {idle} conns up+pinged in {:.2}s on {io_threads} IO threads",
        idle_setup.as_secs_f64()
    );
    println!(
        "active fleet: {active} conns x {reqs_per_conn} reqs (window {window}) \
         = {total_reqs} reqs in {active_wall:.2}s ({rps:.0} req/s), 0 errors"
    );
    println!(
        "latency us:   p50 {p50:.0}  p99 {p99:.0}  p99.9 {p999:.0}"
    );
    println!(
        "threads:      baseline {:?} idle {:?} after {:?}",
        threads_baseline, threads_idle, threads_after
    );
    println!(
        "rss kb:       baseline {:?} idle {:?} after {:?}",
        rss_baseline, rss_idle, rss_after
    );

    let opt = |v: Option<u64>| Json::Num(v.map(|x| x as f64).unwrap_or(-1.0));
    let json = Json::obj(vec![
        ("idle_conns", Json::Num(idle as f64)),
        ("active_conns", Json::Num(active as f64)),
        ("io_threads", Json::Num(io_threads as f64)),
        ("requests", Json::Num(total_reqs as f64)),
        ("errors", Json::Num(errors as f64)),
        ("req_per_s", Json::Num(rps)),
        ("latency_p50_us", Json::Num(p50)),
        ("latency_p99_us", Json::Num(p99)),
        ("latency_p999_us", Json::Num(p999)),
        ("idle_setup_s", Json::Num(idle_setup.as_secs_f64())),
        ("rss_baseline_kb", opt(rss_baseline)),
        ("rss_idle_kb", opt(rss_idle)),
        ("rss_after_kb", opt(rss_after)),
        (
            "threads_baseline",
            Json::Num(threads_baseline.map(|t| t as f64).unwrap_or(-1.0)),
        ),
        (
            "threads_after",
            Json::Num(threads_after.map(|t| t as f64).unwrap_or(-1.0)),
        ),
    ]);
    println!("\nBENCH_conn.json {}", json.dump());
    if let Err(e) = std::fs::write("BENCH_conn.json", json.dump()) {
        eprintln!("could not write BENCH_conn.json: {e}");
    }

    // clean shutdown: drop the fleet, then stop the server via the warm conn
    drop(idle_conns);
    (&admin).write_all(b"{\"cmd\":\"shutdown\"}\n").ok();
    let mut buf = [0u8; 256];
    let _ = (&admin).read(&mut buf);
    drop(admin);
    let _ = run_handle.join();
}
