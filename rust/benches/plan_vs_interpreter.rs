//! Interpreted walk vs compiled plan: the cost of the interpretive layer
//! on the hottest path.
//!
//! Three execution strategies per (arch, batch):
//!
//! * **interpreted** — `PfpExecutor::forward_interpreted`: re-walks the
//!   layer list, re-decides conversions, heap-allocates every
//!   intermediate tensor (the pre-lowering executor);
//! * **planned** — `PfpExecutor::forward`: cached `CompiledPlan` +
//!   workspace, plus the output-tensor copy the executor API pays;
//! * **plan-raw** — `CompiledPlan::execute` on a reused workspace: the
//!   steady-state zero-allocation serving path;
//! * **plan-fused** — plan-raw with epilogue fusion forced on
//!   (`FusePolicy::On`): dense/conv → ReLU (→ convert) chains run as
//!   single register-resident steps, no intermediate buffer round trips.
//!
//! * **plan-f16 / plan-bf16** — plan-raw with the storage-precision knob
//!   forced: weights packed to 16-bit at compile, inter-layer activations
//!   round-tripped through u16 staging, all accumulation f32.
//!
//! Batches 1 and 64 bracket the paper's serving regime (single-request
//! latency vs a full batcher bucket). Emits the usual bench table/JSON
//! lines plus a `BENCH_plan.json` summary (interpreted vs planned vs
//! fused ns/row, the fused-over-unfused `fuse_speedup`, and per-precision
//! `{arch}_b{batch}_{f16,bf16}_ns_row` + `..._speedup_vs_f32` columns) so
//! future PRs can track the trajectory.

use std::sync::Arc;

use pfp::model::{Arch, FusePolicy, PfpExecutor, PosteriorWeights, Schedules};
use pfp::plan::{CompiledPlan, PlanMode};
use pfp::profiling::Profiler;
use pfp::tensor::Tensor;
use pfp::util::bench::{bench, black_box, report, BenchOpts};
use pfp::util::half::Precision;
use pfp::util::json::Json;
use pfp::util::prop::Gen;

fn input(arch: &Arch, batch: usize) -> Tensor {
    let mut g = Gen::new(0xBEE);
    let n = batch * arch.input_len();
    Tensor::new(
        vec![batch, arch.input_len()],
        (0..n).map(|_| g.f32_in(0.0, 1.0)).collect(),
    )
    .unwrap()
}

fn main() {
    let opts = BenchOpts::from_env();
    let mut results = Vec::new();
    let mut summary: Vec<(String, Json)> = Vec::new();

    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = PosteriorWeights::synthetic(&arch, 1);
        for batch in [1usize, 64] {
            let x = input(&arch, batch);

            let mut interp =
                PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1));
            let r_interp = bench(
                &format!("{} b{batch} interpreted", arch.name),
                opts,
                || {
                    black_box(interp.forward_interpreted(&x));
                },
            );

            let mut planned =
                PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1));
            let r_planned = bench(&format!("{} b{batch} planned", arch.name), opts, || {
                black_box(planned.forward(&x));
            });

            let plan = CompiledPlan::compile(
                &arch,
                Arc::new(weights.clone()),
                &Schedules::tuned(1),
                batch,
                PlanMode::Pfp,
            )
            .unwrap();
            let mut ws = plan.workspace();
            let mut off = Profiler::new(false);
            let r_raw = bench(&format!("{} b{batch} plan-raw", arch.name), opts, || {
                let (mu, var) = plan.execute(x.data(), &mut ws, &mut off);
                black_box((mu[0], var[0]));
            });

            // mixed-precision legs: the same plan with f16/bf16 moment
            // storage (packed weights + u16 activation staging), per the
            // acceptance criteria: ns/row and speedup-vs-f32 per precision
            let mut prec_runs = Vec::new();
            for prec in [Precision::F16, Precision::Bf16] {
                let pplan = CompiledPlan::compile(
                    &arch,
                    Arc::new(weights.clone()),
                    &Schedules::tuned(1).with_precision_override(Some(prec)),
                    batch,
                    PlanMode::Pfp,
                )
                .unwrap();
                assert!(pplan.num_packed_steps() > 0);
                let mut pws = pplan.workspace();
                let r = bench(
                    &format!("{} b{batch} plan-{prec}", arch.name),
                    opts,
                    || {
                        let (mu, var) = pplan.execute(x.data(), &mut pws, &mut off);
                        black_box((mu[0], var[0]));
                    },
                );
                prec_runs.push((prec, r));
            }

            let fused_plan = CompiledPlan::compile(
                &arch,
                Arc::new(weights.clone()),
                &Schedules::tuned(1).with_fuse(FusePolicy::On),
                batch,
                PlanMode::Pfp,
            )
            .unwrap();
            assert!(fused_plan.num_fused_steps() > 0);
            let mut fused_ws = fused_plan.workspace();
            let r_fused =
                bench(&format!("{} b{batch} plan-fused", arch.name), opts, || {
                    let (mu, var) = fused_plan.execute(x.data(), &mut fused_ws, &mut off);
                    black_box((mu[0], var[0]));
                });

            let ns_row = |median_s: f64| median_s * 1e9 / batch as f64;
            summary.push((
                format!("{}_b{batch}_interpreted_ns_row", arch.name),
                Json::Num(ns_row(r_interp.median_s)),
            ));
            summary.push((
                format!("{}_b{batch}_planned_ns_row", arch.name),
                Json::Num(ns_row(r_planned.median_s)),
            ));
            summary.push((
                format!("{}_b{batch}_plan_raw_ns_row", arch.name),
                Json::Num(ns_row(r_raw.median_s)),
            ));
            summary.push((
                format!("{}_b{batch}_plan_fused_ns_row", arch.name),
                Json::Num(ns_row(r_fused.median_s)),
            ));
            summary.push((
                format!("{}_b{batch}_speedup", arch.name),
                Json::Num(if r_raw.median_s > 0.0 {
                    r_interp.median_s / r_raw.median_s
                } else {
                    0.0
                }),
            ));
            summary.push((
                format!("{}_b{batch}_fuse_speedup", arch.name),
                Json::Num(if r_fused.median_s > 0.0 {
                    r_raw.median_s / r_fused.median_s
                } else {
                    0.0
                }),
            ));
            for (prec, r) in &prec_runs {
                summary.push((
                    format!("{}_b{batch}_{prec}_ns_row", arch.name),
                    Json::Num(ns_row(r.median_s)),
                ));
                summary.push((
                    format!("{}_b{batch}_{prec}_speedup_vs_f32", arch.name),
                    Json::Num(if r.median_s > 0.0 {
                        r_raw.median_s / r.median_s
                    } else {
                        0.0
                    }),
                ));
            }

            results.push(r_interp);
            results.push(r_planned);
            results.push(r_raw);
            results.push(r_fused);
            results.extend(prec_runs.into_iter().map(|(_, r)| r));
        }
    }

    report("plan vs interpreter (single probabilistic forward pass)", &results);

    let refs: Vec<(&str, Json)> =
        summary.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    let json = Json::obj(refs);
    println!("\nBENCH_plan.json {}", json.dump());
    if let Err(e) = std::fs::write("BENCH_plan.json", json.dump()) {
        eprintln!("could not write BENCH_plan.json: {e}");
    }
}
