//! Table 2 — manual optimization techniques for the PFP dense operator,
//! on the paper's workload: 3-layer MLP Dense 1 (10x784x100).
//!
//! Rows mirror the paper: each knob alone on the untuned baseline, each
//! knob *removed* from the otherwise-fully-tuned schedule, tiling alone,
//! and all-opts. Expected shape: loop reordering / unrolling / parallel
//! help alone; vectorization alone *hurts* (strided lanes in the naive
//! order); the all-on-except-tiling schedule is best.
//! (Single hardware core here: the parallel rows measure scheduling
//! overhead, not speedup — EXPERIMENTS.md reports this explicitly.)

use pfp::ops::dense::{pfp_dense_joint, DenseArgs};
use pfp::ops::{LoopOrder, Schedule};
use pfp::tensor::Tensor;
use pfp::util::bench::{bench, black_box, report, BenchOpts};
use pfp::util::prop::Gen;

fn main() {
    let opts = BenchOpts::from_env();
    let threads = pfp::util::threadpool::default_threads().max(2);
    let (m, k, n) = (10usize, 784usize, 100usize);
    let mut g = Gen::new(7);
    let x_mu = Tensor::new(vec![m, k], g.normal_vec(m * k, 1.0)).unwrap();
    let x_e2 = x_mu.squared();
    let w_mu = Tensor::new(vec![n, k], g.normal_vec(n * k, 0.2)).unwrap();
    let w_e2 = w_mu.squared();
    let args = DenseArgs {
        x_mu: &x_mu, x_aux: &x_e2, w_mu: &w_mu, w_aux: &w_e2,
        b_mu: None, b_var: None,
    };

    let baseline = Schedule::baseline();
    let tuned = Schedule::tuned(1); // all opts except tiling, single knob off below

    let cases: Vec<(&str, Schedule)> = vec![
        ("baseline (no tuning)", baseline),
        // --- single knob ON over the baseline (paper "Other Opt. OFF")
        ("tiling alone (16x64)", Schedule::tiled(16, 64)),
        ("reorder alone (Mnk)", baseline.with_order(LoopOrder::Mnk)),
        ("vectorize alone", baseline.with_vectorize(true)),
        (
            "parallel alone",
            baseline.with_threads(threads),
        ),
        ("unroll alone (x8)", baseline.with_unroll(8)),
        // --- single knob OFF from tuned (paper "Other Opt. ON")
        ("tuned minus reorder", tuned.with_order(LoopOrder::Mkn)),
        ("tuned minus vectorize", tuned.with_vectorize(false)),
        ("tuned minus unroll", tuned.with_unroll(1)),
        ("tuned + tiling (no stoch.)", tuned.with_tiles(16, 64)),
        // --- all optimizations
        ("all opts (tuned, 1 thread)", tuned),
        ("all opts + parallel", Schedule::tuned(threads)),
    ];

    let mut results = Vec::new();
    for (label, sched) in &cases {
        results.push(bench(label, opts, || {
            black_box(pfp_dense_joint(&args, sched));
        }));
    }
    report("Table 2 — manual optimizations, PFP dense (MLP Dense 1, batch 10)", &results);

    let base_ms = results[0].median_s;
    println!("\nspeedup vs untuned baseline:");
    for r in &results {
        println!("  {:<28} {:>6.2}x", r.name, base_ms / r.median_s);
    }
}
