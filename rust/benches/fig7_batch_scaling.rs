//! Fig. 7 — latency and speedup vs mini-batch size: PFP (single pass,
//! per-batch-tuned) against the SVI baseline (30 sampled passes).
//!
//! Expected shape: per-image SVI latency explodes as batch shrinks (fixed
//! 30-passes cost amortised over fewer images) while PFP stays nearly
//! flat, giving the paper's multi-order-of-magnitude speedups at batch 1
//! and tens-to-hundreds x at batch 256.

use pfp::model::{Arch, PfpExecutor, PosteriorWeights, Schedules, SviExecutor};
use pfp::runtime::Manifest;
use pfp::tensor::Tensor;
use pfp::util::bench::{bench, black_box, BenchOpts};

fn main() {
    let dir = pfp::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let fast = std::env::var("PFP_BENCH_FAST").as_deref() == Ok("1");
    let mut opts = BenchOpts::from_env();
    opts.max_iters = if fast { 5 } else { 30 };
    let svi_samples = 30;

    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let arch = Arch::mlp();
    let calib = manifest.calibration_factor("mlp");
    let weights = PosteriorWeights::load(&dir, &arch, calib).unwrap();

    let batches: &[usize] = if fast {
        &[1, 10, 100]
    } else {
        &[1, 2, 4, 8, 16, 32, 64, 128, 256]
    };

    println!(
        "{:>6} {:>12} {:>12} {:>14} {:>14} {:>10}",
        "batch", "pfp ms", "svi-30 ms", "pfp us/img", "svi us/img", "speedup"
    );
    for &b in batches {
        let x = Tensor::full(vec![b, 784], 0.4);
        let mut pfp_exec =
            PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1));
        let r_pfp = bench(&format!("pfp b{b}"), opts, || {
            black_box(pfp_exec.forward(&x));
        });
        let mut svi_exec =
            SviExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1), 5);
        let mut svi_opts = opts;
        svi_opts.max_iters = if fast { 2 } else { 6 };
        svi_opts.warmup_iters = 1;
        let r_svi = bench(&format!("svi b{b}"), svi_opts, || {
            black_box(svi_exec.forward_n(&x, svi_samples));
        });
        let pfp_img = r_pfp.median_s * 1e6 / b as f64;
        let svi_img = r_svi.median_s * 1e6 / b as f64;
        println!(
            "{:>6} {:>12.3} {:>12.3} {:>14.1} {:>14.1} {:>9.1}x",
            b,
            r_pfp.median_s * 1e3,
            r_svi.median_s * 1e3,
            pfp_img,
            svi_img,
            svi_img / pfp_img
        );
        println!(
            "JSON {{\"batch\":{b},\"pfp_ms\":{:.5},\"svi_ms\":{:.5},\"speedup\":{:.2}}}",
            r_pfp.median_s * 1e3,
            r_svi.median_s * 1e3,
            svi_img / pfp_img
        );
    }
    println!(
        "\npaper shape (Fig. 7): speedup grows as batch shrinks — 13-112x at\n\
         b=256 up to 550-4200x at b=1 on ARM. The SVI row here is the native\n\
         rust baseline with per-pass weight sampling, matching the paper's\n\
         'sample + forward' accounting."
    );
}
