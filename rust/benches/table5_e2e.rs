//! Table 5 — algorithm comparison across execution substrates:
//! deterministic NN (untuned/tuned) vs SVI (30 samples) vs PFP
//! (untuned/tuned), for the MLP and LeNet-5 at batches 10 and 100.
//!
//! The paper's processor axis (Cortex-A53/A72/A76) is substituted by the
//! execution-backend axis available on this host: native Rust operators
//! (1 thread), native with the parallel schedule, and the AOT-compiled
//! XLA artifact through PJRT (the deep-learning-compiler analog).

use pfp::model::{
    Arch, DetExecutor, PfpExecutor, PosteriorWeights, Schedules, SviExecutor,
};
use pfp::runtime::{Engine, Manifest};
use pfp::tensor::Tensor;
use pfp::util::bench::{bench, black_box, BenchOpts};

fn main() {
    let dir = pfp::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let fast = std::env::var("PFP_BENCH_FAST").as_deref() == Ok("1");
    let mut opts = BenchOpts::from_env();
    opts.max_iters = if fast { 3 } else { 20 };
    let svi_samples = 30;
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    // the XLA/PJRT substrate is optional (stub engine without the
    // `xla-runtime` feature) — native rows must still run without it
    let engine = match Engine::new(&dir) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("(xla substrate unavailable: {e})");
            None
        }
    };
    let threads = pfp::util::threadpool::default_threads().max(2);

    let batches: &[usize] = if fast { &[10] } else { &[10, 100] };
    println!(
        "{:<7} {:>5} {:<14} {:>13} {:>11} {:>13} {:>11} {:>9}",
        "arch", "batch", "substrate", "det untuned", "det tuned", "pfp untuned", "pfp tuned", "svi-30"
    );

    for arch_name in ["mlp", "lenet"] {
        let arch = Arch::by_name(arch_name).unwrap();
        let calib = manifest.calibration_factor(arch_name);
        let weights = PosteriorWeights::load(&dir, &arch, calib).unwrap();
        for &b in batches {
            let mut shape = vec![b];
            shape.extend_from_slice(&arch.input_shape);
            let x = Tensor::full(shape, 0.4);

            // --- native substrates
            for (substrate, sched_tuned) in [
                ("native-1T", Schedules::tuned(1)),
                ("native-par", Schedules::tuned(threads)),
            ] {
                let det_unt = DetExecutor::new(arch.clone(), weights.clone(), Schedules::baseline());
                let det_tun =
                    DetExecutor::new(arch.clone(), weights.clone(), sched_tuned.clone());
                let mut pfp_unt =
                    PfpExecutor::new(arch.clone(), weights.clone(), Schedules::baseline());
                let mut pfp_tun =
                    PfpExecutor::new(arch.clone(), weights.clone(), sched_tuned.clone());
                let mut svi =
                    SviExecutor::new(arch.clone(), weights.clone(), sched_tuned, 9);

                let r_du = bench("det untuned", opts, || {
                    black_box(det_unt.forward(&x));
                });
                let r_dt = bench("det tuned", opts, || {
                    black_box(det_tun.forward(&x));
                });
                let r_pu = bench("pfp untuned", opts, || {
                    black_box(pfp_unt.forward(&x));
                });
                let r_pt = bench("pfp tuned", opts, || {
                    black_box(pfp_tun.forward(&x));
                });
                let mut svi_opts = opts;
                svi_opts.max_iters = if fast { 2 } else { 5 };
                svi_opts.warmup_iters = 1;
                let r_svi = bench("svi", svi_opts, || {
                    black_box(svi.forward_n(&x, svi_samples));
                });
                println!(
                    "{:<7} {:>5} {:<14} {:>11.3}ms {:>9.3}ms {:>11.3}ms {:>9.3}ms {:>7.1}ms",
                    arch_name, b, substrate,
                    r_du.median_ms(), r_dt.median_ms(),
                    r_pu.median_ms(), r_pt.median_ms(), r_svi.median_ms()
                );
                println!(
                    "JSON {{\"arch\":\"{arch_name}\",\"batch\":{b},\"substrate\":\"{substrate}\",\
                     \"det_untuned_ms\":{:.4},\"det_tuned_ms\":{:.4},\"pfp_untuned_ms\":{:.4},\
                     \"pfp_tuned_ms\":{:.4},\"svi_ms\":{:.4},\"speedup_pfp_vs_svi\":{:.1},\
                     \"slowdown_pfp_vs_det\":{:.2}}}",
                    r_du.median_ms(), r_dt.median_ms(), r_pu.median_ms(),
                    r_pt.median_ms(), r_svi.median_ms(),
                    r_svi.median_ms() / r_pt.median_ms(),
                    r_pt.median_ms() / r_dt.median_ms()
                );
            }

            // --- XLA/PJRT substrate (tuned-by-compiler; no untuned column)
            let pfp_name = format!("model_{arch_name}_pfp_b{b}");
            let det_name = format!("model_{arch_name}_det_b{b}");
            if let Some((pfp_m, det_m)) = engine.as_ref().and_then(|eng| {
                match (eng.load(&pfp_name, &weights), eng.load(&det_name, &weights)) {
                    (Ok(p), Ok(d)) => Some((p, d)),
                    _ => None,
                }
            }) {
                let r_det = bench("xla det", opts, || {
                    black_box(det_m.execute(&x).unwrap());
                });
                let r_pfp = bench("xla pfp", opts, || {
                    black_box(pfp_m.execute(&x).unwrap());
                });
                // SVI on XLA: rust-side sampling + N det executions
                let mut rng = pfp::util::rng::SplitMix64::new(5);
                let mut svi_opts = opts;
                svi_opts.max_iters = if fast { 2 } else { 5 };
                svi_opts.warmup_iters = 1;
                let entry = manifest.entry(&det_name).unwrap().clone();
                let r_svi = bench("xla svi", svi_opts, || {
                    for _ in 0..svi_samples {
                        // sampling + re-transfer per posterior sample is part
                        // of the measured SVI cost (as in the Pyro baseline)
                        let sampled = entry.sampled_tensors(&weights, &mut rng);
                        let refs: Vec<&Tensor> = sampled.iter().collect();
                        black_box(det_m.execute_with_weights(&x, &refs).unwrap());
                    }
                });
                println!(
                    "{:<7} {:>5} {:<14} {:>11} {:>9.3}ms {:>11} {:>9.3}ms {:>7.1}ms",
                    arch_name, b, "xla-pjrt", "-", r_det.median_ms(), "-",
                    r_pfp.median_ms(), r_svi.median_ms()
                );
                println!(
                    "JSON {{\"arch\":\"{arch_name}\",\"batch\":{b},\"substrate\":\"xla-pjrt\",\
                     \"det_tuned_ms\":{:.4},\"pfp_tuned_ms\":{:.4},\"svi_ms\":{:.4},\
                     \"speedup_pfp_vs_svi\":{:.1}}}",
                    r_det.median_ms(), r_pfp.median_ms(), r_svi.median_ms(),
                    r_svi.median_ms() / r_pfp.median_ms()
                );
            }
        }
    }
    println!(
        "\npaper shape (Table 5): PFP ~4-11x slower than deterministic; PFP vs\n\
         SVI-30 speedups of 23-990x depending on arch/batch; tuning helps both\n\
         det and PFP substantially."
    );
}
