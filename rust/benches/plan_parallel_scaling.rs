//! Parallel scaling of the planned path: `CompiledPlan::execute` ns/row
//! at tile-task counts (threads) x batch sizes — the Fig. 7 batch-scaling
//! story applied to the compiled executor, and the acceptance gate for
//! the plan-time work-partitioning refactor (planned throughput at >= 2
//! threads must beat the serial planned path at batch 64).
//!
//! Every configuration is the *same* math (row partitioning is
//! bit-identical to serial — asserted here on the fly, not just in the
//! test suite), so the table isolates pure dispatch + scaling behaviour:
//! threads = 1 is the zero-dispatch serial walk, threads > 1 pays one
//! gang broadcast per parallel step.
//!
//! Emits the usual bench table/JSON lines plus a `BENCH_threads.json`
//! summary (`<arch>_b<batch>_t<threads>_ns_row` keys and per-batch
//! best-parallel speedups) so CI can archive the perf trajectory across
//! PRs.

use std::sync::Arc;

use pfp::model::{Arch, PosteriorWeights, Schedules};
use pfp::plan::{CompiledPlan, PlanMode};
use pfp::profiling::Profiler;
use pfp::tensor::Tensor;
use pfp::util::bench::{bench, black_box, report, BenchOpts};
use pfp::util::json::Json;
use pfp::util::prop::Gen;
use pfp::util::threadpool::default_threads;

fn input(arch: &Arch, batch: usize) -> Tensor {
    let mut g = Gen::new(0x5CA1E);
    let n = batch * arch.input_len();
    Tensor::new(
        vec![batch, arch.input_len()],
        (0..n).map(|_| g.f32_in(0.0, 1.0)).collect(),
    )
    .unwrap()
}

fn main() {
    let opts = BenchOpts::from_env();
    let mut thread_counts = vec![1usize, 2, 4, default_threads()];
    thread_counts.sort_unstable();
    thread_counts.dedup();

    let mut results = Vec::new();
    let mut summary: Vec<(String, Json)> = Vec::new();

    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = PosteriorWeights::synthetic(&arch, 1);
        for batch in [1usize, 64] {
            let x = input(&arch, batch);
            let mut serial_ns = 0.0f64;
            let mut best_parallel_ns = f64::INFINITY;
            let mut serial_out: Option<(Vec<f32>, Vec<f32>)> = None;
            for &t in &thread_counts {
                let plan = CompiledPlan::compile(
                    &arch,
                    Arc::new(weights.clone()),
                    &Schedules::tuned(1).with_plan_threads(t),
                    batch,
                    PlanMode::Pfp,
                )
                .unwrap();
                let mut ws = plan.workspace();
                let mut off = Profiler::new(false);
                // determinism spot-check: every thread count must produce
                // the exact bits the serial plan does
                {
                    let (mu, var) = plan.execute(x.data(), &mut ws, &mut off);
                    match &serial_out {
                        None => serial_out = Some((mu.to_vec(), var.to_vec())),
                        Some((smu, svar)) => {
                            assert_eq!(smu.as_slice(), mu, "{} b{batch} t{t} mu", arch.name);
                            assert_eq!(svar.as_slice(), var, "{} b{batch} t{t} var", arch.name);
                        }
                    }
                }
                let r = bench(
                    &format!("{} b{batch} planned t{t}", arch.name),
                    opts,
                    || {
                        let (mu, var) = plan.execute(x.data(), &mut ws, &mut off);
                        black_box((mu[0], var[0]));
                    },
                );
                let ns_row = r.median_s * 1e9 / batch as f64;
                if t == 1 {
                    serial_ns = ns_row;
                } else {
                    best_parallel_ns = best_parallel_ns.min(ns_row);
                }
                summary.push((
                    format!("{}_b{batch}_t{t}_ns_row", arch.name),
                    Json::Num(ns_row),
                ));
                results.push(r);
            }
            summary.push((
                format!("{}_b{batch}_parallel_speedup", arch.name),
                Json::Num(if best_parallel_ns > 0.0 && best_parallel_ns.is_finite() {
                    serial_ns / best_parallel_ns
                } else {
                    0.0
                }),
            ));
        }
    }

    report(
        "planned parallel scaling (tile tasks x batch, bit-identical across threads)",
        &results,
    );

    let refs: Vec<(&str, Json)> =
        summary.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    let json = Json::obj(refs);
    println!("\nBENCH_threads.json {}", json.dump());
    if let Err(e) = std::fs::write("BENCH_threads.json", json.dump()) {
        eprintln!("could not write BENCH_threads.json: {e}");
    }
}
