//! Registry churn: hot load/swap/unload while the service keeps serving.
//!
//! The multi-model registry's operational claim is that admin traffic is
//! cheap relative to inference: a `swap` publishes a new version with an
//! atomic pointer cutover, in-flight requests drain on the version they
//! were pinned to at submit, and the old executor (plan cache included)
//! frees at refcount zero. This bench measures that claim directly, with
//! synthetic posteriors (no trained artifacts needed):
//!
//! * **steady ns/row** — blocking single-request latency through a
//!   registry lane, cold plan already compiled (the pure serving cost a
//!   churning admin plane must not disturb);
//! * **cutover latency** — wall time of `admin_swap` itself (NPZ load +
//!   checksum + atomic publish) and, separately, the first post-swap
//!   request (which pays the new version's cold plan compile);
//! * **churn loop** — load/swap/unload cycles with pipelined requests
//!   interleaved across every cutover, asserting zero dropped or error
//!   responses and correct version attribution throughout.
//!
//! Emits `BENCH_registry.json` (committed into `bench/` by CI's
//! bench-perf job as part of the perf trajectory).

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use pfp::coordinator::{protocol, ProtoVersion, ServerConfig, Service};
use pfp::model::{Arch, PosteriorWeights, SchedulesBuilder};
use pfp::registry::Registry;
use pfp::util::json::Json;
use pfp::util::stats;

fn write_weights(tag: &str, seed: u64) -> std::path::PathBuf {
    let arch = Arch::mlp();
    let path = std::env::temp_dir().join(format!(
        "pfp_bench_registry_{}_{tag}.npz",
        std::process::id()
    ));
    PosteriorWeights::synthetic(&arch, seed).save_npz(&path).unwrap();
    path
}

fn request(id: u64, input: &[f32]) -> protocol::Request {
    protocol::Request { id, model: "mlp".into(), input: input.to_vec() }
}

fn main() {
    let fast = std::env::var("PFP_BENCH_FAST").as_deref() == Ok("1");
    let steady_reqs = if fast { 20 } else { 200 };
    let churn_rounds = if fast { 3 } else { 12 };
    let reqs_per_wave = if fast { 8 } else { 32 };
    let input = vec![0.5f32; 784];

    let mut cfg = ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
    cfg.batcher.max_batch = 8;
    cfg.batcher.max_wait = Duration::from_millis(1);
    let mut svc = Service::new(cfg);
    svc.attach_registry(
        Arc::new(Registry::new(None, true, SchedulesBuilder::tuned(1))),
        1.0,
    );
    let svc = Arc::new(svc);

    let p_even = write_weights("even", 2);
    let p_odd = write_weights("odd", 3);
    svc.admin_load("mlp", &p_even.to_string_lossy(), None, None).unwrap();

    // -- steady-state serving cost (plan warm after the first request) --
    let mut steady_ns = Vec::with_capacity(steady_reqs);
    for i in 0..steady_reqs {
        let t = Instant::now();
        let resp = svc.infer_blocking(request(i as u64, &input));
        let dt = t.elapsed().as_secs_f64() * 1e9;
        assert!(resp.result.is_ok(), "steady request {i} failed");
        if i > 0 {
            steady_ns.push(dt); // drop the cold-compile first request
        }
    }

    // -- churn loop: swap every round, requests pipelined across it --
    let mut swap_ns = Vec::with_capacity(churn_rounds);
    let mut first_post_swap_ns = Vec::with_capacity(churn_rounds);
    let mut next_id = steady_reqs as u64;
    for round in 0..churn_rounds {
        let (tx, rx) = channel();
        for _ in 0..reqs_per_wave {
            svc.submit_with_proto(request(next_id, &input), tx.clone(), ProtoVersion::V1)
                .expect("submit");
            next_id += 1;
        }
        let path = if round % 2 == 0 { &p_odd } else { &p_even };
        let t = Instant::now();
        let ack = svc.admin_swap("mlp", &path.to_string_lossy(), None, None).unwrap();
        swap_ns.push(t.elapsed().as_secs_f64() * 1e9);
        let version = ack.num_field("version").unwrap() as u64;

        // the swap boundary: everything above served <= version-1, the
        // first request below pays the new version's cold plan compile
        let t = Instant::now();
        let resp = svc.infer_blocking(request(next_id, &input));
        first_post_swap_ns.push(t.elapsed().as_secs_f64() * 1e9);
        next_id += 1;
        assert!(resp.result.is_ok());
        assert_eq!(resp.model_version, version, "post-swap request on old version");

        for _ in 0..reqs_per_wave {
            svc.submit_with_proto(request(next_id, &input), tx.clone(), ProtoVersion::V1)
                .expect("submit");
            next_id += 1;
        }
        drop(tx);
        let mut got = 0usize;
        for resp in rx.iter() {
            assert!(
                resp.result.is_ok(),
                "round {round}: churn must drop zero requests, id {} errored",
                resp.id
            );
            assert!(resp.model_version >= version - 1 && resp.model_version <= version);
            got += 1;
        }
        assert_eq!(got, 2 * reqs_per_wave, "round {round}: lost responses");
    }

    // -- unload/load cycle: full teardown + cold re-admission --
    let mut reload_ns = Vec::with_capacity(churn_rounds);
    for _ in 0..churn_rounds {
        let t = Instant::now();
        svc.admin_unload("mlp").unwrap();
        svc.admin_load("mlp", &p_even.to_string_lossy(), None, None).unwrap();
        reload_ns.push(t.elapsed().as_secs_f64() * 1e9);
    }
    let resp = svc.infer_blocking(request(next_id, &input));
    assert!(resp.result.is_ok(), "service must serve after reload churn");

    let registry = svc.registry().unwrap();
    println!("== registry churn (synthetic mlp posterior) ==");
    println!("{:<26} {:>12} {:>12} {:>7}", "case", "median", "p95", "n");
    for (name, xs) in [
        ("steady infer ns/row", &steady_ns),
        ("swap cutover ns", &swap_ns),
        ("first post-swap req ns", &first_post_swap_ns),
        ("unload+load cycle ns", &reload_ns),
    ] {
        println!(
            "{:<26} {:>12.0} {:>12.0} {:>7}",
            name,
            stats::median(xs),
            stats::percentile(xs, 95.0),
            xs.len()
        );
    }
    println!(
        "churn: {churn_rounds} swaps + {churn_rounds} unload/load cycles, \
         {} interleaved requests, 0 errors; plan bytes resident: {}",
        churn_rounds * (2 * reqs_per_wave + 1),
        registry.total_plan_bytes()
    );

    let json = Json::obj(vec![
        ("steady_infer_ns_median", Json::Num(stats::median(&steady_ns))),
        ("steady_infer_ns_p95", Json::Num(stats::percentile(&steady_ns, 95.0))),
        ("swap_cutover_ns_median", Json::Num(stats::median(&swap_ns))),
        ("swap_cutover_ns_p95", Json::Num(stats::percentile(&swap_ns, 95.0))),
        (
            "first_post_swap_ns_median",
            Json::Num(stats::median(&first_post_swap_ns)),
        ),
        ("reload_cycle_ns_median", Json::Num(stats::median(&reload_ns))),
        ("churn_rounds", Json::Num(churn_rounds as f64)),
        ("interleaved_requests", Json::Num((churn_rounds * (2 * reqs_per_wave + 1)) as f64)),
        ("errors", Json::Num(0.0)),
    ]);
    println!("\nBENCH_registry.json {}", json.dump());
    if let Err(e) = std::fs::write("BENCH_registry.json", json.dump()) {
        eprintln!("could not write BENCH_registry.json: {e}");
    }

    std::fs::remove_file(&p_even).ok();
    std::fs::remove_file(&p_odd).ok();
}
