//! Table 3 — Max Pool implementations: generic reduction vs hand-
//! vectorized fixed-k=2, alone and inside the whole LeNet-5 PFP network.
//!
//! The paper's auto-tuning column is mirrored by the only schedule freedom
//! the pool has on this host: chunked multi-threaded execution (the
//! "automatically generated schedule"). Expected shape: the vectorized
//! pool beats the generic reduction; applying the automatic schedule to
//! the hand-vectorized pool makes it *worse* (paper: 3.54ms -> 27.28ms),
//! which on one core shows up as pure scheduling overhead.

use pfp::model::{Arch, PfpExecutor, PosteriorWeights, Schedules};
use pfp::ops::maxpool::{pfp_maxpool2_vectorized, pfp_maxpool_generic};
use pfp::tensor::{ProbTensor, Rep, Tensor};
use pfp::util::bench::{bench, black_box, report, BenchOpts};
use pfp::util::prop::Gen;

/// "Auto-tuned" pool: the generic reduction split across worker threads —
/// the closest analog of handing the operator to the Meta Scheduler.
fn pool_generic_autotuned(input: &ProbTensor, threads: usize) -> ProbTensor {
    let s = input.mu.shape().to_vec();
    let n = s[0];
    if n < 2 || threads < 2 {
        return pfp_maxpool_generic(input, 2, 2);
    }
    // split the batch across threads; stitch results
    let chunk_rows = s[1] * s[2] * s[3];
    let ranges = pfp::util::threadpool::split_ranges(n, threads);
    let outputs: Vec<ProbTensor> = crossbeam_scope(input, &ranges, chunk_rows);
    // concatenate
    let oh = s[2] / 2;
    let ow = s[3] / 2;
    let mut mu = Vec::with_capacity(n * s[1] * oh * ow);
    let mut var = Vec::with_capacity(n * s[1] * oh * ow);
    for o in outputs {
        mu.extend_from_slice(o.mu.data());
        var.extend_from_slice(o.aux.data());
    }
    ProbTensor::new(
        Tensor::new(vec![n, s[1], oh, ow], mu).unwrap(),
        Tensor::new(vec![n, s[1], oh, ow], var).unwrap(),
        Rep::Var,
    )
}

fn crossbeam_scope(
    input: &ProbTensor,
    ranges: &[std::ops::Range<usize>],
    chunk_rows: usize,
) -> Vec<ProbTensor> {
    let s = input.mu.shape().to_vec();
    let mut out: Vec<Option<ProbTensor>> = ranges.iter().map(|_| None).collect();
    crossbeam_utils::thread::scope(|sc| {
        for (slot, r) in out.iter_mut().zip(ranges) {
            let s = s.clone();
            sc.spawn(move |_| {
                let nb = r.end - r.start;
                let mu = Tensor::new(
                    vec![nb, s[1], s[2], s[3]],
                    input.mu.data()[r.start * chunk_rows..r.end * chunk_rows].to_vec(),
                )
                .unwrap();
                let var = Tensor::new(
                    vec![nb, s[1], s[2], s[3]],
                    input.aux.data()[r.start * chunk_rows..r.end * chunk_rows].to_vec(),
                )
                .unwrap();
                *slot = Some(pfp_maxpool_generic(
                    &ProbTensor::new(mu, var, Rep::Var),
                    2,
                    2,
                ));
            });
        }
    })
    .unwrap();
    out.into_iter().flatten().collect()
}

fn main() {
    let opts = BenchOpts::from_env();
    let threads = pfp::util::threadpool::default_threads().max(2);
    let mut g = Gen::new(3);
    let batch = 10;

    // LeNet pool-1 shape: 6@24x24 (the expensive pool in Table 4)
    let shape = vec![batch, 6, 24, 24];
    let nel: usize = shape.iter().product();
    let input = ProbTensor::new(
        Tensor::new(shape.clone(), g.normal_vec(nel, 1.0)).unwrap(),
        Tensor::new(shape, g.var_vec(nel, 0.5)).unwrap(),
        Rep::Var,
    );

    let mut results = Vec::new();
    results.push(bench("pool only / generic, no tuning", opts, || {
        black_box(pfp_maxpool_generic(&input, 2, 2));
    }));
    results.push(bench("pool only / generic, auto-tuned", opts, || {
        black_box(pool_generic_autotuned(&input, threads));
    }));
    results.push(bench("pool only / vectorized k=2 (scalar isa)", opts, || {
        black_box(pfp_maxpool2_vectorized(&input, pfp::ops::Isa::Scalar));
    }));
    results.push(bench("pool only / vectorized k=2 (simd isa)", opts, || {
        black_box(pfp_maxpool2_vectorized(&input, pfp::ops::Isa::Native));
    }));
    results.push(bench("pool only / vectorized + auto sched", opts, || {
        // the paper's pathological row: auto-scheduling the hand-tuned op
        let v = pool_generic_autotuned(&input, threads);
        black_box(pfp_maxpool2_vectorized(&v, pfp::ops::Isa::Native));
    }));

    // ---- whole-network effect (Table 3 right column) ---------------------
    let dir = pfp::artifacts_dir();
    if dir.join("weights_lenet.npz").exists() {
        let arch = Arch::lenet();
        let w = PosteriorWeights::load(&dir, &arch, 0.3).unwrap();
        let x = Tensor::full(vec![batch, 1, 28, 28], 0.4);
        for (label, vectorized) in [
            ("LeNet-5 e2e / generic pool", false),
            ("LeNet-5 e2e / vectorized pool", true),
        ] {
            let mut sched = Schedules::tuned(1);
            sched.vectorized_pool = vectorized;
            let mut exec = PfpExecutor::new(arch.clone(), w.clone(), sched);
            results.push(bench(label, opts, || {
                black_box(exec.forward(&x));
            }));
        }
    } else {
        eprintln!("(artifacts missing: skipping whole-network rows)");
    }

    report("Table 3 — Max Pool implementations (batch 10)", &results);
    println!(
        "\npaper shape: vectorized < generic; auto-tuning the vectorized pool hurts;\n\
         e2e network gains from the vectorized pool."
    );
}
