//! Scalar vs explicit-SIMD microkernels on the paper's workloads.
//!
//! Dense: the Table-2 MLP layers (784→100, 100→100, 100→10) plus LeNet's
//! conv2 im2col'd dense workload (`[B*64, 150] x [16, 150]`), at batch 1
//! and 64, through the planned serial row kernel (`dense_rows_into`,
//! `JointEq12`) — tuned `Mnk` schedule, only the `isa` knob differs. The
//! moment-matched ReLU is benched too (the transcendental-heavy
//! elementwise op the SIMD layer accelerates most).
//!
//! Each case asserts scalar↔SIMD parity (the 1e-4 cross-ISA contract)
//! before timing, so a broken backend can't post a fast-but-wrong number.
//! Emits `BENCH_simd.json`: scalar/simd/f16-packed ns per batch row,
//! effective GB/s per leg (unique-bytes traffic model — activations,
//! weights, biases read once, both moment outputs written once; the f16
//! leg counts its weight operands at 2 bytes), and speedups per shape.
//! The CI bench gate compiles this target on every push and the perf job
//! uploads the JSON artifact. The acceptance bar for the SIMD layer:
//! `dense1_b64_speedup > 1` on AVX2/NEON hosts (the batch-64 Table-2
//! shape; trivially ~1 when detection reports scalar).

use pfp::ops::dense::{
    dense_rows_into, dense_rows_packed_into, DenseSlices, JointEq12, PackedDenseSlices,
};
use pfp::ops::relu::pfp_relu_rows_into;
use pfp::ops::simd::{self, Isa, PackedSlice};
use pfp::ops::{Epilogue, Schedule};
use pfp::util::bench::{bench, black_box, report, BenchOpts};
use pfp::util::half::{narrow, Precision};
use pfp::util::json::Json;
use pfp::util::prop::Gen;

struct Case {
    name: &'static str,
    /// rows per batch element (1 for dense; OH*OW patch rows for conv)
    rows_per_item: usize,
    k: usize,
    n: usize,
}

fn main() {
    let opts = BenchOpts::from_env();
    let backend = simd::detect();
    println!("detected SIMD backend: {}", backend.name());

    let cases = [
        // Table-2 MLP dense layers on their true shapes
        Case { name: "dense1_784x100", rows_per_item: 1, k: 784, n: 100 },
        Case { name: "dense2_100x100", rows_per_item: 1, k: 100, n: 100 },
        Case { name: "dense3_100x10", rows_per_item: 1, k: 100, n: 10 },
        // LeNet conv2 as the plan executes it: im2col'd dense rows
        Case { name: "conv2_im2col_150x16", rows_per_item: 64, k: 150, n: 16 },
    ];

    let mut results = Vec::new();
    let mut summary: Vec<(String, Json)> = Vec::new();
    let mut g = Gen::new(0x51D);

    for batch in [1usize, 64] {
        for case in &cases {
            let m = batch * case.rows_per_item;
            let (k, n) = (case.k, case.n);
            let x_mu = g.normal_vec(m * k, 1.0);
            let x_e2: Vec<f32> = x_mu.iter().map(|&v| v * v + 0.1).collect();
            let w_mu = g.normal_vec(n * k, 0.2);
            let w_e2: Vec<f32> = w_mu.iter().map(|&v| v * v + 0.01).collect();
            let b_mu = g.normal_vec(n, 0.5);
            let b_var = g.var_vec(n, 0.1);
            let slices = DenseSlices {
                m,
                k,
                n,
                x_mu: &x_mu,
                x_aux: &x_e2,
                w_mu: &w_mu,
                w_aux: &w_e2,
                b_mu: Some(&b_mu),
                b_var: Some(&b_var),
            };
            let scalar = Schedule::tuned(1).with_isa(Isa::Scalar);
            let native = Schedule::tuned(1).with_isa(Isa::Native);

            // parity guard: a broken backend must not post a number
            let mut mu_s = vec![0.0f32; m * n];
            let mut var_s = vec![0.0f32; m * n];
            let mut mu_n = vec![0.0f32; m * n];
            let mut var_n = vec![0.0f32; m * n];
            dense_rows_into::<JointEq12>(&slices, &scalar, Epilogue::None, 0..m, &mut mu_s, &mut var_s);
            dense_rows_into::<JointEq12>(&slices, &native, Epilogue::None, 0..m, &mut mu_n, &mut var_n);
            for i in 0..m * n {
                assert!(
                    (mu_s[i] - mu_n[i]).abs() <= 1e-4 + 1e-4 * mu_s[i].abs(),
                    "{} b{batch}: scalar/simd mu diverged at {i}",
                    case.name
                );
                assert!(
                    (var_s[i] - var_n[i]).abs() <= 1e-3 + 1e-3 * var_s[i].abs(),
                    "{} b{batch}: scalar/simd var diverged at {i}",
                    case.name
                );
            }

            let r_scalar = bench(&format!("{} b{batch} scalar", case.name), opts, || {
                dense_rows_into::<JointEq12>(
                    &slices, &scalar, Epilogue::None, 0..m, &mut mu_s, &mut var_s,
                );
                black_box(mu_s[0]);
            });
            let r_simd = bench(
                &format!("{} b{batch} {}", case.name, backend.name()),
                opts,
                || {
                    dense_rows_into::<JointEq12>(
                        &slices, &native, Epilogue::None, 0..m, &mut mu_n, &mut var_n,
                    );
                    black_box(mu_n[0]);
                },
            );

            // mixed-precision leg: the same workload with f16 weight
            // storage through the packed-operand kernel (activations stay
            // f32 here — the kernel-level packing is the weight traffic)
            let wm_bits: Vec<u16> =
                w_mu.iter().map(|&v| narrow(Precision::F16, v)).collect();
            let wa_bits: Vec<u16> =
                w_e2.iter().map(|&v| narrow(Precision::F16, v)).collect();
            let pslices = PackedDenseSlices {
                m,
                k,
                n,
                x_mu: &x_mu,
                x_aux: &x_e2,
                w_mu: PackedSlice::U16(Precision::F16, &wm_bits),
                w_aux: PackedSlice::U16(Precision::F16, &wa_bits),
                b_mu: Some(&b_mu),
                b_var: Some(&b_var),
            };
            let r_f16 = bench(&format!("{} b{batch} f16 {}", case.name, backend.name()), opts, || {
                dense_rows_packed_into::<JointEq12>(
                    &pslices, &native, Epilogue::None, 0..m, &mut mu_n, &mut var_n,
                );
                black_box(mu_n[0]);
            });

            // unique-bytes traffic model for the effective-bandwidth
            // column: both activation operands + both weight operands +
            // biases read once, both moment outputs written once
            let f32_bytes = 4 * (2 * m * k + 2 * n * k + 2 * n + 2 * m * n);
            let f16_bytes = 4 * (2 * m * k + 2 * n + 2 * m * n) + 2 * (2 * n * k);
            let gbs = |bytes: usize, median_s: f64| {
                if median_s > 0.0 { bytes as f64 / median_s / 1e9 } else { 0.0 }
            };
            let ns_row = |median_s: f64| median_s * 1e9 / batch as f64;
            summary.push((
                format!("{}_b{batch}_scalar_ns_row", case.name),
                Json::Num(ns_row(r_scalar.median_s)),
            ));
            summary.push((
                format!("{}_b{batch}_simd_ns_row", case.name),
                Json::Num(ns_row(r_simd.median_s)),
            ));
            summary.push((
                format!("{}_b{batch}_f16_ns_row", case.name),
                Json::Num(ns_row(r_f16.median_s)),
            ));
            summary.push((
                format!("{}_b{batch}_scalar_gbs", case.name),
                Json::Num(gbs(f32_bytes, r_scalar.median_s)),
            ));
            summary.push((
                format!("{}_b{batch}_simd_gbs", case.name),
                Json::Num(gbs(f32_bytes, r_simd.median_s)),
            ));
            summary.push((
                format!("{}_b{batch}_f16_gbs", case.name),
                Json::Num(gbs(f16_bytes, r_f16.median_s)),
            ));
            summary.push((
                format!("{}_b{batch}_speedup", case.name),
                Json::Num(if r_simd.median_s > 0.0 {
                    r_scalar.median_s / r_simd.median_s
                } else {
                    0.0
                }),
            ));
            summary.push((
                format!("{}_b{batch}_f16_speedup_vs_f32", case.name),
                Json::Num(if r_f16.median_s > 0.0 {
                    r_simd.median_s / r_f16.median_s
                } else {
                    0.0
                }),
            ));
            results.push(r_scalar);
            results.push(r_simd);
            results.push(r_f16);
        }
    }

    // the elementwise transcendental hot spot: moment-matched ReLU on a
    // LeNet-conv1-sized activation (batch 64)
    {
        let n = 64 * 6 * 24 * 24;
        let mu = g.normal_vec(n, 2.0);
        let var = g.var_vec(n, 1.0);
        let mut om = vec![0.0f32; n];
        let mut oe = vec![0.0f32; n];
        let r_scalar = bench("relu_moments b64 scalar", opts, || {
            pfp_relu_rows_into(Isa::Scalar, &mu, &var, 0..n, &mut om, &mut oe);
            black_box(om[0]);
        });
        let r_simd = bench(&format!("relu_moments b64 {}", backend.name()), opts, || {
            pfp_relu_rows_into(Isa::Native, &mu, &var, 0..n, &mut om, &mut oe);
            black_box(om[0]);
        });
        summary.push((
            "relu_b64_scalar_ns_row".into(),
            Json::Num(r_scalar.median_s * 1e9 / 64.0),
        ));
        summary.push((
            "relu_b64_simd_ns_row".into(),
            Json::Num(r_simd.median_s * 1e9 / 64.0),
        ));
        // 2 operands in + 2 moments out, 4 bytes each
        let relu_bytes = 16 * n;
        let gbs = |median_s: f64| {
            if median_s > 0.0 { relu_bytes as f64 / median_s / 1e9 } else { 0.0 }
        };
        summary.push(("relu_b64_scalar_gbs".into(), Json::Num(gbs(r_scalar.median_s))));
        summary.push(("relu_b64_simd_gbs".into(), Json::Num(gbs(r_simd.median_s))));
        summary.push((
            "relu_b64_speedup".into(),
            Json::Num(if r_simd.median_s > 0.0 {
                r_scalar.median_s / r_simd.median_s
            } else {
                0.0
            }),
        ));
        results.push(r_scalar);
        results.push(r_simd);
    }

    summary.push(("backend".into(), Json::Str(backend.name().to_string())));

    report("scalar vs explicit SIMD microkernels", &results);

    let refs: Vec<(&str, Json)> =
        summary.iter().map(|(kk, v)| (kk.as_str(), v.clone())).collect();
    let json = Json::obj(refs);
    println!("\nBENCH_simd.json {}", json.dump());
    if let Err(e) = std::fs::write("BENCH_simd.json", json.dump()) {
        eprintln!("could not write BENCH_simd.json: {e}");
    }
}
