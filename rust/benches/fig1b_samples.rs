//! Fig. 1b — influence of predictive sample count on the uncertainty
//! metrics: Softmax Entropy stabilises with very few samples while Total
//! Predictive Uncertainty and Mutual Information (especially on OOD data)
//! need many samples for reliable OOD detection.
//!
//! Uses the trained PFP logit moments (Eq. 11 logit sampling) on the
//! synthetic Dirty-MNIST test sets, exactly the protocol behind the
//! paper's figure; also reports the post-processing cost per sample count.

use pfp::data::DirtyMnist;
use pfp::model::{Arch, PfpExecutor, PosteriorWeights, Schedules};
use pfp::runtime::Manifest;
use pfp::uncertainty;
use pfp::util::bench::{bench, black_box, BenchOpts};

fn main() {
    let dir = pfp::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let fast = std::env::var("PFP_BENCH_FAST").as_deref() == Ok("1");
    let opts = BenchOpts::from_env();
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let arch = Arch::mlp();
    let weights =
        PosteriorWeights::load(&dir, &arch, manifest.calibration_factor("mlp")).unwrap();
    let data = DirtyMnist::load(&dir).unwrap();
    let n = if fast { 100 } else { 400 };

    let mut exec = PfpExecutor::new(arch, weights, Schedules::tuned(1));
    let (mu_in, var_in) = exec.forward(&data.test_mnist.x.first_rows(n));
    let (mu_ood, var_ood) = exec.forward(&data.test_ood.x.first_rows(n));
    let (mu_amb, var_amb) = exec.forward(&data.test_ambiguous.x.first_rows(n));

    // ground truth at a large sample count
    let ref_samples = if fast { 300 } else { 2000 };
    let u_ref_ood = uncertainty::pfp_uncertainty(&mu_ood, &var_ood, ref_samples, 99);
    let ref_mi = mean(&u_ref_ood.mi);

    println!(
        "{:>8} {:>10} {:>10} {:>10} {:>12} {:>12} {:>10}",
        "samples", "SME(ood)", "Total(ood)", "MI(ood)", "MI err vs ref", "AUROC(MI)", "postproc"
    );
    let counts: &[usize] = if fast {
        &[1, 3, 10, 30, 100]
    } else {
        &[1, 2, 3, 5, 10, 20, 30, 50, 100, 200, 400]
    };
    for &s in counts {
        let u_in = uncertainty::pfp_uncertainty(&mu_in, &var_in, s, 7);
        let u_amb = uncertainty::pfp_uncertainty(&mu_amb, &var_amb, s, 7);
        let u_ood = uncertainty::pfp_uncertainty(&mu_ood, &var_ood, s, 7);
        let in_mi: Vec<f64> = u_in.mi.iter().chain(&u_amb.mi).cloned().collect();
        let roc = uncertainty::auroc(&u_ood.mi, &in_mi);
        let r = bench(&format!("postproc s{s}"), opts, || {
            black_box(uncertainty::pfp_uncertainty(&mu_ood, &var_ood, s, 7));
        });
        println!(
            "{:>8} {:>10.4} {:>10.4} {:>10.4} {:>11.1}% {:>12.3} {:>8.2}ms",
            s,
            mean(&u_ood.sme),
            mean(&u_ood.total),
            mean(&u_ood.mi),
            100.0 * (mean(&u_ood.mi) - ref_mi).abs() / ref_mi.max(1e-9),
            roc,
            r.median_ms()
        );
        println!(
            "JSON {{\"samples\":{s},\"sme_ood\":{:.5},\"total_ood\":{:.5},\"mi_ood\":{:.5},\
             \"auroc\":{:.4},\"postproc_ms\":{:.4}}}",
            mean(&u_ood.sme),
            mean(&u_ood.total),
            mean(&u_ood.mi),
            roc,
            r.median_ms()
        );
    }
    println!(
        "\npaper shape (Fig. 1b): SME stable from ~1 sample; Total/MI rise with\n\
         sample count and need >=30 samples to stabilise for OOD detection."
    );
}

fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len().max(1) as f64
}
