//! Blocking vs pipelined single-connection serving throughput.
//!
//! The paper's Fig. 7 / Table 5 point is that PFP latency is nearly
//! batch-size independent, so a server wins by coalescing concurrent
//! requests into one probabilistic forward pass. This bench drives ONE
//! TCP connection two ways against a native-PFP service (synthetic
//! weights — no artifacts needed):
//!
//! * **blocking** — strict request -> response lockstep (the pre-rewrite
//!   front end's behaviour): the batcher only ever sees one request at a
//!   time, so every forward pass runs at batch 1;
//! * **pipelined** — `pipeline_depth = max_batch` requests kept in
//!   flight: the batcher coalesces the window into large batches.
//!
//! Expected shape: blocking throughput is flat in `max_batch` (mean batch
//! size pinned at 1) while pipelined throughput grows with the batch
//! bucket, approaching the batch-size-independent forward-pass rate.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use pfp::coordinator::{
    protocol, BatcherConfig, NativePfpBackend, Server, ServerConfig, Service,
};
use pfp::model::{Arch, PosteriorWeights, Schedules};

struct RunStats {
    reqs_per_s: f64,
    mean_batch: f64,
}

fn run_mode(max_batch: usize, window: usize, n_requests: usize, input: &[f32]) -> RunStats {
    let mut cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        pipeline_depth: window,
        ..Default::default()
    };
    cfg.batcher = BatcherConfig {
        max_batch,
        max_wait: Duration::from_millis(2),
        capacity: 4096,
    };
    let mut svc = Service::new(cfg);
    let arch = Arch::mlp();
    let weights = PosteriorWeights::synthetic(&arch, 1);
    svc.register(
        "mlp",
        784,
        Box::new(NativePfpBackend::new(arch, weights, Schedules::tuned(1))),
    );
    let svc = Arc::new(svc);
    let server = Server::bind(svc.clone()).unwrap();
    let addr = server.addr;
    let run_handle = std::thread::spawn(move || server.run());

    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut wire = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    writeln!(wire, r#"{{"cmd":"hello","pipeline":true}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"hello\":true"), "handshake failed: {line}");

    let t0 = Instant::now();
    let mut sent = 0usize;
    let mut received = 0usize;
    while sent < window.min(n_requests) {
        writeln!(wire, "{}", protocol::request_json(sent as u64, "mlp", input)).unwrap();
        sent += 1;
    }
    while received < n_requests {
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = protocol::Response::parse(line.trim()).unwrap();
        assert!(resp.result.is_ok(), "request {} failed", resp.id);
        received += 1;
        if sent < n_requests {
            writeln!(wire, "{}", protocol::request_json(sent as u64, "mlp", input)).unwrap();
            sent += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let mean_batch = svc.metrics.mean_batch_size();

    writeln!(wire, r#"{{"cmd":"shutdown"}}"#).ok();
    line.clear();
    reader.read_line(&mut line).ok();
    drop(wire);
    drop(reader);
    let _ = run_handle.join();

    RunStats { reqs_per_s: n_requests as f64 / wall, mean_batch }
}

fn main() {
    let fast = std::env::var("PFP_BENCH_FAST").as_deref() == Ok("1");
    let n_requests = if fast { 60 } else { 400 };
    let input = vec![0.5f32; 784];

    println!(
        "{:>6} {:>14} {:>14} {:>9} {:>12} {:>12}",
        "batch", "blocking r/s", "pipelined r/s", "speedup", "mean b(blk)", "mean b(pipe)"
    );
    for &b in &[1usize, 10, 64] {
        let blocking = run_mode(b, 1, n_requests, &input);
        let pipelined = run_mode(b, b, n_requests, &input);
        println!(
            "{:>6} {:>14.0} {:>14.0} {:>8.2}x {:>12.2} {:>12.2}",
            b,
            blocking.reqs_per_s,
            pipelined.reqs_per_s,
            pipelined.reqs_per_s / blocking.reqs_per_s,
            blocking.mean_batch,
            pipelined.mean_batch
        );
        println!(
            "JSON {{\"batch\":{b},\"blocking_rps\":{:.2},\"pipelined_rps\":{:.2},\
             \"speedup\":{:.3},\"pipelined_mean_batch\":{:.3}}}",
            blocking.reqs_per_s,
            pipelined.reqs_per_s,
            pipelined.reqs_per_s / blocking.reqs_per_s,
            pipelined.mean_batch
        );
    }
    println!(
        "\nexpected shape: blocking throughput is ~flat in max_batch (every\n\
         pass runs at batch 1); pipelined throughput rises with the window\n\
         because PFP's per-pass cost is nearly batch-size independent (Fig. 7)."
    );
}
