//! Fig. 5 — operator implementation comparison for the PFP dense layer:
//! the Eq. 5 -> Eq. 12 reformulation and separate vs joint mean/variance
//! operators, on the paper's layer shapes (MLP Dense 1/2/3 at batch 10).
//!
//! Expected shape (paper): joint beats separate everywhere; the Eq. 12
//! raw-moment form beats the Eq. 5 original form; joint+Eq.12 is best.

use pfp::ops::dense::{
    pfp_dense_joint, pfp_dense_joint_eq5, pfp_dense_separate, DenseArgs,
};
use pfp::ops::Schedule;
use pfp::tensor::Tensor;
use pfp::util::bench::{bench, black_box, report, BenchOpts};
use pfp::util::prop::Gen;

fn main() {
    let opts = BenchOpts::from_env();
    let sched = Schedule::tuned(1);
    let mut results = Vec::new();
    let mut g = Gen::new(42);

    // (label, M, K, N) — MLP layers at batch 10 + a LeNet conv-as-matmul
    let shapes = [
        ("dense1 10x784x100", 10usize, 784usize, 100usize),
        ("dense2 10x100x100", 10, 100, 100),
        ("dense3 10x100x10", 10, 100, 10),
        ("conv2-im2col 640x150x16", 640, 150, 16),
    ];

    for (label, m, k, n) in shapes {
        let x_mu = Tensor::new(vec![m, k], g.normal_vec(m * k, 1.0)).unwrap();
        let x_var = Tensor::new(vec![m, k], g.var_vec(m * k, 0.5)).unwrap();
        let x_e2 = x_mu.zip(&x_var, |a, b| a * a + b).unwrap();
        let w_mu = Tensor::new(vec![n, k], g.normal_vec(n * k, 0.2)).unwrap();
        let w_var = Tensor::new(vec![n, k], g.var_vec(n * k, 0.02)).unwrap();
        let w_e2 = w_mu.zip(&w_var, |a, b| a * a + b).unwrap();

        let raw = DenseArgs {
            x_mu: &x_mu, x_aux: &x_e2, w_mu: &w_mu, w_aux: &w_e2,
            b_mu: None, b_var: None,
        };
        let eq5 = DenseArgs {
            x_mu: &x_mu, x_aux: &x_e2, w_mu: &w_mu, w_aux: &w_var,
            b_mu: None, b_var: None,
        };

        results.push(bench(&format!("{label} / joint eq12"), opts, || {
            black_box(pfp_dense_joint(&raw, &sched));
        }));
        results.push(bench(&format!("{label} / joint eq5"), opts, || {
            black_box(pfp_dense_joint_eq5(&eq5, &sched));
        }));
        results.push(bench(&format!("{label} / separate eq12"), opts, || {
            black_box(pfp_dense_separate(&raw, &sched, false));
        }));
        results.push(bench(&format!("{label} / separate eq5"), opts, || {
            black_box(pfp_dense_separate(&eq5, &sched, true));
        }));
    }

    report("Fig. 5 — PFP dense: joint vs separate x Eq.12 vs Eq.5", &results);

    // summary speedups per shape
    println!("\nspeedup of joint+eq12 over each variant:");
    for chunk in results.chunks(4) {
        let base = chunk[0].median_s;
        println!(
            "{:<28} eq5 {:.2}x | sep-eq12 {:.2}x | sep-eq5 {:.2}x",
            chunk[0].name.split('/').next().unwrap(),
            chunk[1].median_s / base,
            chunk[2].median_s / base,
            chunk[3].median_s / base
        );
    }
}
