//! Ablation bench for the design choices DESIGN.md calls out (not a paper
//! exhibit — supporting evidence for this repo's implementation choices):
//!
//! 1. conv lowering: im2col -> scheduled joint dense vs direct 7-loop conv;
//! 2. first-layer specialisation: Eq. 13 kernel vs generic Eq. 12 kernel
//!    fed `x_e2 = x^2, w_e2 = mu^2 + var` (mathematically identical);
//! 3. representation precompute: storing `E[w^2]` once vs converting
//!    per-forward (the paper's "weights stored as second raw moments");
//! 4. pool tree vs sequential fold association (accuracy-neutral cost).

use pfp::ops::conv::{pfp_conv2d_direct, pfp_conv2d_joint, ConvArgs};
use pfp::ops::dense::{pfp_dense_first, pfp_dense_joint, DenseArgs};
use pfp::ops::maxpool::{pfp_maxpool2_vectorized, pfp_maxpool_generic};
use pfp::ops::Schedule;
use pfp::tensor::{ProbTensor, Rep, Tensor};
use pfp::util::bench::{bench, black_box, report, BenchOpts};
use pfp::util::prop::Gen;

fn main() {
    let opts = BenchOpts::from_env();
    let sched = Schedule::tuned(1);
    let mut g = Gen::new(21);
    let mut results = Vec::new();

    // ---- 1. conv lowering (LeNet conv2 shape, batch 10) ------------------
    let (n, ci, co, hw, k) = (10usize, 6usize, 16usize, 12usize, 5usize);
    let x_mu = Tensor::new(vec![n, ci, hw, hw], g.normal_vec(n * ci * hw * hw, 1.0)).unwrap();
    let x_var = Tensor::new(vec![n, ci, hw, hw], g.var_vec(n * ci * hw * hw, 0.5)).unwrap();
    let x_e2 = x_mu.zip(&x_var, |m, v| m * m + v).unwrap();
    let x = ProbTensor::new(x_mu.clone(), x_e2, Rep::E2);
    let w_mu = Tensor::new(vec![co, ci, k, k], g.normal_vec(co * ci * k * k, 0.2)).unwrap();
    let w_var = Tensor::new(vec![co, ci, k, k], g.var_vec(co * ci * k * k, 0.02)).unwrap();
    let w_e2 = w_mu.zip(&w_var, |m, v| m * m + v).unwrap();
    let cargs = ConvArgs { w_mu: &w_mu, w_aux: &w_e2, b_mu: None, b_var: None };
    results.push(bench("conv2: im2col + scheduled dense", opts, || {
        black_box(pfp_conv2d_joint(&x, &cargs, &sched));
    }));
    results.push(bench("conv2: direct 7-loop", opts, || {
        black_box(pfp_conv2d_direct(&x, &cargs));
    }));

    // ---- 2. first-layer specialisation (MLP dense1, batch 10) ------------
    let (m, kk, nn) = (10usize, 784usize, 100usize);
    let xd = Tensor::new(vec![m, kk], g.normal_vec(m * kk, 1.0)).unwrap();
    let xd_sq = xd.squared();
    let wm = Tensor::new(vec![nn, kk], g.normal_vec(nn * kk, 0.2)).unwrap();
    let wv = Tensor::new(vec![nn, kk], g.var_vec(nn * kk, 0.02)).unwrap();
    let we = wm.zip(&wv, |a, b| a * a + b).unwrap();
    results.push(bench("first layer: Eq.13 specialised", opts, || {
        black_box(pfp_dense_first(
            &DenseArgs {
                x_mu: &xd, x_aux: &xd_sq, w_mu: &wm, w_aux: &wv,
                b_mu: None, b_var: None,
            },
            &sched,
        ));
    }));
    results.push(bench("first layer: generic Eq.12", opts, || {
        black_box(pfp_dense_joint(
            &DenseArgs {
                x_mu: &xd, x_aux: &xd_sq, w_mu: &wm, w_aux: &we,
                b_mu: None, b_var: None,
            },
            &sched,
        ));
    }));

    // ---- 3. E[w^2] precompute vs per-forward conversion -------------------
    results.push(bench("weights: E[w^2] precomputed", opts, || {
        black_box(pfp_dense_joint(
            &DenseArgs {
                x_mu: &xd, x_aux: &xd_sq, w_mu: &wm, w_aux: &we,
                b_mu: None, b_var: None,
            },
            &sched,
        ));
    }));
    results.push(bench("weights: E[w^2] converted per call", opts, || {
        let we_fresh = wm.zip(&wv, |a, b| a * a + b).unwrap();
        black_box(pfp_dense_joint(
            &DenseArgs {
                x_mu: &xd, x_aux: &xd_sq, w_mu: &wm, w_aux: &we_fresh,
                b_mu: None, b_var: None,
            },
            &sched,
        ));
    }));

    // ---- 4. pool association order ---------------------------------------
    let pm = Tensor::new(vec![10, 6, 24, 24], g.normal_vec(10 * 6 * 24 * 24, 1.0)).unwrap();
    let pv = Tensor::new(vec![10, 6, 24, 24], g.var_vec(10 * 6 * 24 * 24, 0.5)).unwrap();
    let pool_in = ProbTensor::new(pm, pv, Rep::Var);
    results.push(bench("pool: balanced tree (vectorized)", opts, || {
        black_box(pfp_maxpool2_vectorized(&pool_in, pfp::ops::Isa::Native));
    }));
    results.push(bench("pool: sequential fold (generic)", opts, || {
        black_box(pfp_maxpool_generic(&pool_in, 2, 2));
    }));

    report("Ablations — implementation design choices", &results);
    for pair in results.chunks(2) {
        println!(
            "  {:<38} vs alternative: {:.2}x",
            pair[0].name,
            pair[1].median_s / pair[0].median_s
        );
    }
}
