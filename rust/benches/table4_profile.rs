//! Table 4 + Fig. 6 — per-layer latency profile of the PFP MLP and
//! LeNet-5 at mini-batch 10, baseline vs tuned schedules.
//!
//! Emits (a) Table 4 rows: per-layer latency + fraction, baseline and
//! tuned, with per-layer speedups, and (b) Fig. 6 rows: execution-time
//! share per operator *type* (dense / conv2d / relu / maxpool / the
//! representation-conversion "tooling").

use pfp::model::{Arch, PfpExecutor, PosteriorWeights, Schedules};
use pfp::runtime::Manifest;
use pfp::tensor::Tensor;

fn main() {
    let dir = pfp::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let batch = 10;
    let passes = if std::env::var("PFP_BENCH_FAST").as_deref() == Ok("1") {
        5
    } else {
        30
    };

    for arch_name in ["mlp", "lenet"] {
        let arch = Arch::by_name(arch_name).unwrap();
        let calib = manifest.calibration_factor(arch_name);
        let weights = PosteriorWeights::load(&dir, &arch, calib).unwrap();
        let x = Tensor::full(
            {
                let mut s = vec![batch];
                s.extend_from_slice(&arch.input_shape);
                s
            },
            0.4,
        );

        let mut profiles = Vec::new();
        for (label, schedules) in [
            ("baseline", Schedules::baseline()),
            ("tuned", Schedules::tuned(1)),
        ] {
            let mut exec =
                PfpExecutor::new(arch.clone(), weights.clone(), schedules).with_profiling();
            for _ in 0..passes {
                let _ = exec.forward(&x);
            }
            let profile = exec.profiler.take();
            print!("\n{}", profile.render(&format!("Table 4 — {arch_name} b{batch} [{label}]")));
            profiles.push((label, profile));
        }

        // per-layer speedup columns (baseline -> tuned)
        println!("\nper-layer speedup ({arch_name}):");
        let base_rows = profiles[0].1.by_layer();
        let tuned_rows = profiles[1].1.by_layer();
        for br in &base_rows {
            if let Some(tr) = tuned_rows.iter().find(|r| r.label == br.label) {
                println!(
                    "  {:<14} {:>8.3}ms -> {:>8.3}ms  {:>5.1}x",
                    br.label,
                    br.per_pass_ms,
                    tr.per_pass_ms,
                    br.per_pass_ms / tr.per_pass_ms.max(1e-9)
                );
            }
        }
        let b_total = profiles[0].1.total_per_pass_ms();
        let t_total = profiles[1].1.total_per_pass_ms();
        println!(
            "  {:<14} {:>8.3}ms -> {:>8.3}ms  {:>5.1}x",
            "Entire Network",
            b_total,
            t_total,
            b_total / t_total
        );

        // Fig. 6 — share per operator type, tuned configuration
        println!("\nFig. 6 — execution-time share per operator type ({arch_name}, tuned):");
        for r in profiles[1].1.by_op_type() {
            let bar_len = (r.fraction * 40.0).round() as usize;
            println!(
                "  {:<10} {:>5.1}%  {}",
                r.label,
                r.fraction * 100.0,
                "#".repeat(bar_len)
            );
        }
        println!(
            "JSON {{\"arch\":\"{arch_name}\",\"baseline_ms\":{b_total:.4},\"tuned_ms\":{t_total:.4}}}"
        );
    }
    println!(
        "\npaper shape: dense dominates the MLP; LeNet is flatter with ReLU and\n\
         Max Pool prominent; pools do not improve with tuning."
    );
}
