//! Dispatch-overhead micro-benchmark: the persistent worker pool vs the
//! original `crossbeam_utils::thread::scope` spawn-per-call strategy, on
//! the acceptance workload — a 64-row parallel-for with a near-empty
//! body, so the measurement is pure scheduling cost.
//!
//! Acceptance (ISSUE 1): the pool's per-call dispatch must be at least
//! 5x cheaper than spawn-per-call. A spawn/join pair costs tens of
//! microseconds per chunk; pool dispatch is a channel send + latch wait.

use std::sync::atomic::{AtomicU64, Ordering};

use pfp::util::bench::{bench, black_box, report, BenchOpts};
use pfp::util::threadpool::{
    default_threads, parallel_for_in, scoped_parallel_for, ThreadPool,
};

fn main() {
    let opts = BenchOpts::from_env();
    let threads = default_threads().max(2);
    let pool = ThreadPool::new(threads);
    let n_rows = 64usize;
    let sink = AtomicU64::new(0);

    let mut results = Vec::new();
    results.push(bench(
        &format!("scoped spawn-per-call / {n_rows} rows x {threads} threads"),
        opts,
        || {
            scoped_parallel_for(n_rows, threads, |r, _| {
                sink.fetch_add((r.end - r.start) as u64, Ordering::Relaxed);
            });
        },
    ));
    results.push(bench(
        &format!("persistent pool        / {n_rows} rows x {threads} threads"),
        opts,
        || {
            parallel_for_in(&pool, n_rows, threads, |r, _| {
                sink.fetch_add((r.end - r.start) as u64, Ordering::Relaxed);
            });
        },
    ));
    black_box(sink.load(Ordering::Relaxed));

    report("pool dispatch overhead — 64-row parallel-for", &results);
    let scoped_us = results[0].median_s * 1e6;
    let pooled_us = results[1].median_s * 1e6;
    println!(
        "\nper-call dispatch: scoped {scoped_us:.1}us vs pool {pooled_us:.1}us \
         -> {:.1}x lower (acceptance: >= 5x)",
        scoped_us / pooled_us
    );
}
