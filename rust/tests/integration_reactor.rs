//! Reactor front-end regressions: the event-driven connection layer must
//! survive the failure modes that wedged the old thread-per-connection
//! server — a stalled reader may not block anyone else (it is
//! back-pressured into its bounded output buffer and then disconnected),
//! shutdown must drain through the wakeup pipe with no polling tick,
//! oversized lines and half-closed sockets must degrade per-connection
//! rather than per-server, and tenant admission control must shed load
//! with explicit errors.
//!
//! Uses a synthetic stub backend so the suite runs without trained
//! artifacts. The backend's output width is configurable so tests can
//! make responses large enough to fill kernel socket buffers.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use pfp::coordinator::{protocol, Backend, BatcherConfig, Server, ServerConfig, Service};
use pfp::tensor::Tensor;

/// Stub backend: fixed moments with a configurable output width (`out_k`
/// logits per row — wide outputs make each response line large) and an
/// optional per-batch delay (to hold requests in flight deterministically).
struct StubBackend {
    delay: Duration,
    out_k: usize,
}

impl Backend for StubBackend {
    fn infer(&mut self, x: &Tensor) -> pfp::Result<(Tensor, Tensor)> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let b = x.dim(0);
        Ok((
            Tensor::full(vec![b, self.out_k], 0.5),
            Tensor::full(vec![b, self.out_k], 1e-3),
        ))
    }

    fn name(&self) -> String {
        "stub".into()
    }
}

fn service_with(
    delay_ms: u64,
    out_k: usize,
    tweak: impl FnOnce(&mut ServerConfig),
) -> Arc<Service> {
    let mut cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    };
    cfg.batcher = BatcherConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        capacity: 4096,
    };
    tweak(&mut cfg);
    let mut svc = Service::new(cfg);
    svc.register(
        "stub",
        4,
        Box::new(StubBackend { delay: Duration::from_millis(delay_ms), out_k }),
    );
    Arc::new(svc)
}

struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let writer = stream.try_clone().unwrap();
        Self { writer, reader: BufReader::new(stream) }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").unwrap();
    }

    fn recv(&mut self) -> String {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        line.trim().to_string()
    }
}

/// Join `run()`'s thread with a timeout so a hung event loop fails the
/// test instead of wedging the whole suite.
fn join_within(h: std::thread::JoinHandle<pfp::Result<()>>, timeout: Duration) {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let r = h.join();
        let _ = tx.send(r.is_ok());
    });
    rx.recv_timeout(timeout)
        .expect("Server::run did not terminate after shutdown");
}

/// The headline slow-client regression: client A bursts requests with
/// wide responses and never drains its socket. The old front end wedged
/// A's writer thread (and with it A's whole request lane) on a blocking
/// `write`; the reactor must instead fill A's bounded outbox, count it
/// slow, disconnect it — and client B's lockstep traffic must keep
/// working throughout.
#[test]
fn stalled_reader_is_dropped_and_peers_keep_working() {
    let svc = service_with(0, 1024, |cfg| {
        cfg.pipeline_depth = 32;
        cfg.batcher.max_batch = 64;
        cfg.max_outbuf_bytes = 64 * 1024;
        cfg.write_stall = Duration::from_millis(300);
    });
    let server = Server::bind(svc.clone()).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    // client A: pipelined, reads only the hello ack, then stops draining
    let mut a = Client::connect(addr);
    a.send(r#"{"cmd":"hello","pipeline":true}"#);
    assert!(a.recv().contains("\"pipeline\":true"));
    // ~2500 requests x ~8KB responses: far more than the kernel's socket
    // buffers can absorb, so A's outbox must overflow or stall. Writes
    // start failing once the server disconnects A — that is the success
    // path, not an error.
    for i in 0..2500u64 {
        let line = protocol::request_json(i, "stub", &[0.25; 4]);
        if writeln!(a.writer, "{line}").is_err() {
            break;
        }
    }

    // client B: legacy lockstep, must see prompt service the whole time
    let mut b = Client::connect(addr);
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        b.send(r#"{"cmd":"ping"}"#);
        assert!(b.recv().contains("pong"), "peer connection starved");
        if svc.metrics.conns_dropped_slow.load(Ordering::Relaxed) >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "stalled reader was never disconnected (conns_dropped_slow still 0)"
        );
        std::thread::sleep(Duration::from_millis(25));
    }

    // B still gets full inference service after A's eviction
    b.send(&protocol::request_json(9000, "stub", &[0.5; 4]));
    let resp = protocol::Response::parse(&b.recv()).unwrap();
    assert_eq!(resp.id, 9000);
    assert!(resp.result.is_ok());

    drop(a);
    b.send(r#"{"cmd":"shutdown"}"#);
    assert!(b.recv().contains("shutting_down"));
    drop(b);
    join_within(h, Duration::from_secs(10));
}

/// Shutdown is wakeup-pipe-driven: no 200ms poll tick, no TCP self-poke.
/// The whole drain — ack the shutdown, flush it, close an *idle* second
/// connection, join every IO thread — must finish well under the old
/// tick-bounded latency. (`integration_pipeline.rs` keeps the looser
/// historical bound; this is the tight one.)
#[test]
fn shutdown_drains_promptly_without_poll_tick() {
    let svc = service_with(0, 4, |cfg| cfg.pipeline_depth = 8);
    let server = Server::bind(svc).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    let mut c = Client::connect(addr);
    c.send(r#"{"cmd":"hello","pipeline":true}"#);
    assert!(c.recv().contains("\"hello\":true"));
    for i in 0..4u64 {
        c.send(&protocol::request_json(i, "stub", &[0.5; 4]));
    }
    for _ in 0..4 {
        assert!(protocol::Response::parse(&c.recv()).unwrap().result.is_ok());
    }

    // a second, idle connection: shutdown must close it without waiting
    // for it to speak (roundtrip first so it is admitted, not in-flight)
    let mut idle = Client::connect(addr);
    idle.send(r#"{"cmd":"ping"}"#);
    assert!(idle.recv().contains("pong"));

    let t0 = Instant::now();
    c.send(r#"{"cmd":"shutdown"}"#);
    assert!(c.recv().contains("shutting_down"));
    drop(c);
    // the idle peer sees EOF, not a hang
    let mut line = String::new();
    let n = idle.reader.read_line(&mut line).unwrap();
    assert_eq!(n, 0, "idle connection not closed at shutdown: {line:?}");
    join_within(h, Duration::from_secs(2));
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "shutdown drain took {:?} — the poll tick is back",
        t0.elapsed()
    );
}

/// A line longer than `max_line_bytes` gets an explicit error response
/// and bounded buffering — and the connection survives to serve the next
/// well-formed line.
#[test]
fn oversized_line_is_rejected_and_connection_survives() {
    let svc = service_with(0, 4, |cfg| cfg.max_line_bytes = 512);
    let server = Server::bind(svc.clone()).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    let mut c = Client::connect(addr);
    c.send(&"x".repeat(2048));
    let err = c.recv();
    assert!(err.contains("byte limit"), "bad oversize rejection: {err}");
    assert_eq!(svc.metrics.lines_oversized.load(Ordering::Relaxed), 1);

    // same connection, next line: full service
    c.send(r#"{"cmd":"ping"}"#);
    assert!(c.recv().contains("pong"));
    c.send(&protocol::request_json(1, "stub", &[0.5; 4]));
    assert!(protocol::Response::parse(&c.recv()).unwrap().result.is_ok());

    c.send(r#"{"cmd":"shutdown"}"#);
    assert!(c.recv().contains("shutting_down"));
    drop(c);
    join_within(h, Duration::from_secs(10));
}

/// A client that half-closes (FIN on its write side) while a request is
/// still in the backend must receive the in-flight response before the
/// server closes the connection — read-side EOF is not abandonment.
#[test]
fn half_closed_socket_still_receives_in_flight_response() {
    let svc = service_with(300, 4, |_| {});
    let server = Server::bind(svc).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    let c = Client::connect(addr);
    let mut writer = c.writer;
    let mut reader = c.reader;
    writeln!(writer, "{}", protocol::request_json(42, "stub", &[0.5; 4])).unwrap();
    // FIN while the 300ms backend still holds the request
    writer.shutdown(Shutdown::Write).unwrap();

    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = protocol::Response::parse(line.trim()).unwrap();
    assert_eq!(resp.id, 42);
    assert!(resp.result.is_ok(), "in-flight response lost on half-close");
    // after the drained response the server closes its side too
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0);

    // the half-closed conn is gone, so a fresh client shuts the server
    let mut admin = Client::connect(addr);
    admin.send(r#"{"cmd":"shutdown"}"#);
    assert!(admin.recv().contains("shutting_down"));
    drop(admin);
    join_within(h, Duration::from_secs(10));
}

/// Admin commands and inference requests interleaved on one pipelined
/// connection: the codec must hand each decoded line to the right lane
/// and every reply must come back on the same socket.
#[test]
fn admin_and_inference_interleave_on_one_connection() {
    let svc = service_with(20, 4, |cfg| cfg.pipeline_depth = 8);
    let server = Server::bind(svc).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    let mut c = Client::connect(addr);
    c.send(r#"{"cmd":"hello","pipeline":true}"#);
    assert!(c.recv().contains("\"hello\":true"));

    // one write burst: infer, admin, infer, admin
    c.send(&protocol::request_json(1, "stub", &[0.1; 4]));
    c.send(r#"{"cmd":"ping"}"#);
    c.send(&protocol::request_json(2, "stub", &[0.2; 4]));
    c.send(r#"{"cmd":"metrics"}"#);

    let (mut pongs, mut metrics, mut infer_ids) = (0, 0, Vec::new());
    for _ in 0..4 {
        let line = c.recv();
        if line.contains("\"pong\"") {
            pongs += 1;
        } else if line.contains("latency_p50_us") {
            metrics += 1;
        } else {
            let resp = protocol::Response::parse(&line).unwrap();
            assert!(resp.result.is_ok(), "inference {} failed", resp.id);
            infer_ids.push(resp.id);
        }
    }
    assert_eq!(pongs, 1, "ping ack lost in the interleave");
    assert_eq!(metrics, 1, "metrics ack lost in the interleave");
    infer_ids.sort_unstable();
    assert_eq!(infer_ids, vec![1, 2]);

    c.send(r#"{"cmd":"shutdown"}"#);
    assert!(c.recv().contains("shutting_down"));
    drop(c);
    join_within(h, Duration::from_secs(10));
}

/// Per-tenant admission control: with `tenant_quota: 1` and a slow
/// backend, a burst on one model lane gets exactly its quota admitted and
/// the rest shed with explicit `load shed` errors — counted, not queued.
#[test]
fn tenant_quota_sheds_excess_load_over_tcp() {
    let svc = service_with(250, 4, |cfg| {
        cfg.pipeline_depth = 8;
        cfg.tenant_quota = 1;
        cfg.batcher.max_batch = 1;
    });
    let server = Server::bind(svc.clone()).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    let mut c = Client::connect(addr);
    c.send(r#"{"cmd":"hello","pipeline":true}"#);
    assert!(c.recv().contains("\"hello\":true"));
    for i in 0..6u64 {
        c.send(&protocol::request_json(i, "stub", &[0.5; 4]));
    }
    let (mut oks, mut sheds) = (0u64, 0u64);
    for _ in 0..6 {
        let resp = protocol::Response::parse(&c.recv()).unwrap();
        match resp.result {
            Ok(_) => oks += 1,
            Err(e) => {
                assert!(e.contains("load shed"), "unexpected error: {e}");
                assert!(e.contains("tenant quota"), "unexpected error: {e}");
                sheds += 1;
            }
        }
    }
    assert!(oks >= 1, "quota must still admit work");
    assert!(sheds >= 1, "burst past the quota must be shed");
    assert_eq!(oks + sheds, 6);
    assert_eq!(svc.metrics.tenant_rejected.load(Ordering::Relaxed), sheds);

    c.send(r#"{"cmd":"shutdown"}"#);
    assert!(c.recv().contains("shutting_down"));
    drop(c);
    join_within(h, Duration::from_secs(10));
}

/// OS threads in this process (Linux); None elsewhere.
fn process_threads() -> Option<usize> {
    if cfg!(target_os = "linux") {
        std::fs::read_dir("/proc/self/task").ok().map(|d| d.count())
    } else {
        None
    }
}

/// Fifty concurrent connections ride the fixed IO-thread set: thread
/// count must stay flat as connections are added (the old design spawned
/// two threads per connection — +98 here). The bound is generous because
/// sibling tests share the process, but it is far below per-conn growth.
#[test]
fn many_idle_connections_share_the_fixed_io_threads() {
    let svc = service_with(0, 4, |cfg| {
        cfg.max_connections = 64;
        cfg.pool_threads = 2;
    });
    let server = Server::bind(svc).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    // warm one connection so pollers/lanes are all up before the baseline
    let mut first = Client::connect(addr);
    first.send(r#"{"cmd":"ping"}"#);
    assert!(first.recv().contains("pong"));
    let before = process_threads();

    let mut conns: Vec<Client> = (0..49).map(|_| Client::connect(addr)).collect();
    for c in conns.iter_mut() {
        c.send(r#"{"cmd":"ping"}"#);
        assert!(c.recv().contains("pong"), "connection starved while idle peers exist");
    }
    if let (Some(b), Some(a)) = (before, process_threads()) {
        assert!(
            a.saturating_sub(b) < 24,
            "49 extra connections grew the process from {b} to {a} threads"
        );
    }

    drop(conns);
    first.send(r#"{"cmd":"shutdown"}"#);
    assert!(first.recv().contains("shutting_down"));
    drop(first);
    join_within(h, Duration::from_secs(10));
}
