//! Zero-allocation guarantee for steady-state plan execution, asserted
//! with a counting global allocator.
//!
//! This file deliberately holds a single test: the allocator counter is
//! process-global, and libtest runs a binary's tests on concurrent
//! threads — any sibling test would race the measurement window.
//!
//! The guarantee being pinned: after warm-up, `CompiledPlan::execute`
//! with the tuned serial schedule performs **zero** heap allocation —
//! conv im2col runs in plan-owned scratch, activations ping-pong through
//! the workspace, conversions rewrite aux in place, and the disabled
//! profiler is a passthrough. (Parallel schedules pay boxed pool jobs and
//! tiled/`Mkn` loop bodies allocate accumulators; the tuned default does
//! neither.)

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use pfp::model::{Arch, PosteriorWeights, Schedules};
use pfp::ops::Schedule;
use pfp::plan::{CompiledPlan, PlanMode};
use pfp::profiling::Profiler;
use pfp::util::prop::Gen;
use pfp::util::threadpool::ThreadPool;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_execute_performs_zero_heap_allocation() {
    // LeNet exercises every step kind: conv (im2col scratch), relu,
    // vectorized pool, dense, and explicit conversions.
    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = Arc::new(PosteriorWeights::synthetic(&arch, 7));
        // serial, untiled Mnk; a zero-worker lazy pool (never dispatched
        // to) instead of the process-global pool, so no background thread
        // start-up can allocate inside the measurement window
        let schedules = Schedules {
            dense: Schedule::tuned(1),
            conv: Schedule::tuned(1),
            per_layer: Vec::new(),
            vectorized_pool: true,
            relu_threads: 1,
            maxpool_threads: 1,
            pool: Arc::new(ThreadPool::new_lazy(1)),
            records: None,
        };
        let plan =
            CompiledPlan::compile(&arch, weights, &schedules, 2, PlanMode::Pfp).unwrap();
        let mut ws = plan.workspace();
        let mut prof = Profiler::new(false);
        let n = 2 * arch.input_len();
        let x: Vec<f32> = {
            let mut g = Gen::new(3);
            (0..n).map(|_| g.f32_in(0.0, 1.0)).collect()
        };

        // warm-up twice (first call may touch lazily initialized state)
        let _ = plan.execute(&x, &mut ws, &mut prof);
        let _ = plan.execute(&x, &mut ws, &mut prof);

        let before = ALLOCS.load(Ordering::SeqCst);
        let mut checksum = 0.0f32;
        for _ in 0..3 {
            let (mu, var) = plan.execute(&x, &mut ws, &mut prof);
            checksum += mu[0] + var[var.len() - 1];
        }
        let after = ALLOCS.load(Ordering::SeqCst);

        assert!(checksum.is_finite());
        assert_eq!(
            after - before,
            0,
            "{}: steady-state execute allocated {} time(s)",
            arch.name,
            after - before
        );
    }
}
