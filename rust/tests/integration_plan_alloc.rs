//! Zero-allocation guarantee for steady-state plan execution, asserted
//! with a counting global allocator — serial AND parallel.
//!
//! This file deliberately holds a single test: the allocator counter is
//! process-global, and libtest runs a binary's tests on concurrent
//! threads — any sibling test would race the measurement window.
//!
//! The guarantee being pinned: after warm-up, `CompiledPlan::execute`
//! performs **zero** heap allocation —
//!
//! * serial (tuned untiled schedules): conv im2col runs in plan-owned
//!   scratch, activations ping-pong through the workspace, conversions
//!   rewrite aux in place, and the disabled profiler is a passthrough;
//! * parallel + tiled (`plan_threads > 1`, cache-blocked schedules): the
//!   tile partitions were pre-bound at plan time, dispatch goes through
//!   the pool's gang broadcast (`ThreadPool::run_tasks` — no boxed jobs,
//!   no channel sends, no Vec growth), tiles carve disjoint `&mut`
//!   chunks out of the workspace via raw-pointer splits, and the tiled
//!   dense loop body keeps its accumulators in a fixed-size stack array.
//!
//! Only the deliberately naive `Mkn` baseline schedule still allocates in
//! its loop body (it is the Table-2 "no optimizations" row).
//!
//! PR 5: the window is re-asserted under **SIMD execution** — the tuned
//! schedules used below carry `isa: Native`, so the dense reductions and
//! the ReLU/pool elementwise steps run the AVX2/NEON microkernels where
//! the host has them. The one-time ISA detection (`OnceLock` +
//! `PFP_FORCE_SCALAR` env read) resolves during the warm-up passes; the
//! steady-state dispatch is a cached atomic load, the vector kernels work
//! in registers and fixed-size stack lane buffers, so the zero-allocation
//! guarantee holds on every dispatch path (the CI matrix also runs this
//! test with SIMD force-disabled).
//!
//! PR 8: the window is re-asserted with **epilogue fusion forced on**
//! (`FusePolicy::On`) — the fused ReLU(+convert) epilogue works in place
//! on the output tile through fixed-size stack chunk buffers
//! (`EPILOGUE_CHUNK`), so a fused plan allocates exactly as little as an
//! unfused one.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use pfp::model::{Arch, FusePolicy, PosteriorWeights, Schedules};
use pfp::ops::Schedule;
use pfp::plan::{CompiledPlan, PlanMode};
use pfp::profiling::Profiler;
use pfp::util::prop::Gen;
use pfp::util::threadpool::ThreadPool;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Warm up, then assert the next `passes` executes allocate nothing.
fn assert_zero_alloc_window(
    label: &str,
    plan: &CompiledPlan,
    ws: &mut pfp::plan::Workspace,
    x: &[f32],
) {
    let mut prof = Profiler::new(false);
    // warm-up twice (first call may touch lazily initialized state; the
    // parallel path also gets every pool worker hot)
    let _ = plan.execute(x, ws, &mut prof);
    let _ = plan.execute(x, ws, &mut prof);

    let before = ALLOCS.load(Ordering::SeqCst);
    let mut checksum = 0.0f32;
    for _ in 0..3 {
        let (mu, var) = plan.execute(x, ws, &mut prof);
        checksum += mu[0] + var[var.len() - 1];
    }
    let after = ALLOCS.load(Ordering::SeqCst);

    assert!(checksum.is_finite());
    assert_eq!(
        after - before,
        0,
        "{label}: steady-state execute allocated {} time(s)",
        after - before
    );
}

#[test]
fn steady_state_execute_performs_zero_heap_allocation() {
    // --- serial: tuned untiled schedules, zero-worker lazy pool (never
    // dispatched to) so no background thread start-up can allocate inside
    // the measurement window ---
    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = Arc::new(PosteriorWeights::synthetic(&arch, 7));
        let schedules = Schedules {
            dense: Schedule::tuned(1),
            conv: Schedule::tuned(1),
            per_layer: Vec::new(),
            vectorized_pool: true,
            relu_threads: 1,
            maxpool_threads: 1,
            plan_threads: 0,
            isa_override: None, // tuned schedules bind the native ISA
            fuse: FusePolicy::Auto,
            pool: Arc::new(ThreadPool::new_lazy(1)),
            records: None,
        };
        let plan =
            CompiledPlan::compile(&arch, weights, &schedules, 2, PlanMode::Pfp).unwrap();
        let mut ws = plan.workspace();
        let n = 2 * arch.input_len();
        let x: Vec<f32> = {
            let mut g = Gen::new(3);
            (0..n).map(|_| g.f32_in(0.0, 1.0)).collect()
        };
        assert_zero_alloc_window(&format!("{} serial", arch.name), &plan, &mut ws, &x);
    }

    // --- parallel + tiled: plan_threads 3 over an eager 3-worker pool
    // (workers spawned before the window), cache-blocked dense schedule —
    // LeNet exercises every parallel step kind: conv patch-row tiles +
    // plane scatter, dense row tiles, relu element tiles, pool plane
    // tiles, with serial converts in between ---
    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = Arc::new(PosteriorWeights::synthetic(&arch, 8));
        let pool = Arc::new(ThreadPool::new(3));
        let schedules = Schedules {
            dense: Schedule::tuned(1).with_tiles(16, 64),
            conv: Schedule::tuned(1),
            per_layer: Vec::new(),
            vectorized_pool: true,
            relu_threads: 1,
            maxpool_threads: 1,
            plan_threads: 3,
            isa_override: None, // tuned schedules bind the native ISA
            fuse: FusePolicy::Auto,
            pool,
            records: None,
        };
        let plan =
            CompiledPlan::compile(&arch, weights, &schedules, 4, PlanMode::Pfp).unwrap();
        assert!(
            plan.num_parallel_steps() > 0,
            "{}: parallel lowering must actually partition steps",
            arch.name
        );
        let mut ws = plan.workspace();
        let n = 4 * arch.input_len();
        let x: Vec<f32> = {
            let mut g = Gen::new(5);
            (0..n).map(|_| g.f32_in(0.0, 1.0)).collect()
        };
        assert_zero_alloc_window(&format!("{} parallel", arch.name), &plan, &mut ws, &x);
    }

    // --- fused: fusion forced on, serial and parallel — the fused
    // ReLU(+convert) epilogues must keep the window at zero too ---
    for arch in [Arch::mlp(), Arch::lenet()] {
        for plan_threads in [0usize, 3] {
            let weights = Arc::new(PosteriorWeights::synthetic(&arch, 9));
            let pool: Arc<ThreadPool> = if plan_threads > 1 {
                Arc::new(ThreadPool::new(3))
            } else {
                Arc::new(ThreadPool::new_lazy(1))
            };
            let schedules = Schedules {
                dense: Schedule::tuned(1),
                conv: Schedule::tuned(1),
                per_layer: Vec::new(),
                vectorized_pool: true,
                relu_threads: 1,
                maxpool_threads: 1,
                plan_threads,
                isa_override: None,
                fuse: FusePolicy::On,
                pool,
                records: None,
            };
            let plan =
                CompiledPlan::compile(&arch, weights, &schedules, 2, PlanMode::Pfp).unwrap();
            assert!(
                plan.num_fused_steps() > 0,
                "{}: fusion forced on must produce fused steps",
                arch.name
            );
            let mut ws = plan.workspace();
            let n = 2 * arch.input_len();
            let x: Vec<f32> = {
                let mut g = Gen::new(11);
                (0..n).map(|_| g.f32_in(0.0, 1.0)).collect()
            };
            assert_zero_alloc_window(
                &format!("{} fused t{plan_threads}", arch.name),
                &plan,
                &mut ws,
                &x,
            );
        }
    }
}
