//! Cross-language dataset agreement: the Rust synthetic Dirty-MNIST
//! generator must reproduce the python-generated artifact splits
//! draw-for-draw (same SplitMix64 streams; 1e-5 tolerance for libm
//! last-ulp differences in sin/cos/exp/log).

use pfp::data::{synth, DirtyMnist};

#[test]
fn rust_generator_matches_python_npz() {
    let dir = pfp::artifacts_dir();
    if !dir.join("data.npz").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let py = DirtyMnist::load(&dir).unwrap();
    let n = 64; // compare a prefix of each test split
    let g = synth::Generator::new(2025);

    let cases = [
        (synth::Stream::IndomainTest, synth::Kind::Indomain, &py.test_mnist),
        (synth::Stream::AmbiguousTest, synth::Kind::Ambiguous, &py.test_ambiguous),
        (synth::Stream::OodTest, synth::Kind::Ood, &py.test_ood),
    ];
    for (stream, kind, py_split) in cases {
        let rust_split = g.split(stream, n, kind);
        for i in 0..n {
            assert_eq!(
                rust_split.y[i], py_split.y[i],
                "{kind:?} label mismatch at {i}"
            );
            let rx = rust_split.x.row(i);
            let px = py_split.x.row(i);
            let max_diff = rx
                .iter()
                .zip(px)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_diff < 1e-5,
                "{kind:?} sample {i}: max pixel diff {max_diff}"
            );
        }
    }
}

#[test]
fn train_split_statistics_match() {
    let dir = pfp::artifacts_dir();
    if !dir.join("data.npz").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let py = DirtyMnist::load(&dir).unwrap();
    // train split is shuffled in python; check global statistics instead
    let mean: f32 =
        py.train.x.data().iter().sum::<f32>() / py.train.x.len() as f32;
    assert!((0.05..0.6).contains(&mean), "train mean {mean}");
    let classes: std::collections::HashSet<i32> = py.train.y.iter().cloned().collect();
    assert_eq!(classes.len(), 10, "all classes present");
}
