//! Compiled-plan integration: plan-vs-interpreter parity, workspace
//! reuse, per-layer schedule overrides, and plan-cache behaviour through
//! the serving `Service` (the bucket -> compiled-executable mapping).

use std::sync::mpsc::channel;
use std::sync::Arc;

use pfp::coordinator::{protocol, NativePfpBackend, ServerConfig, Service};
use pfp::model::{Arch, PfpExecutor, PosteriorWeights, Schedules};
use pfp::ops::Schedule;
use pfp::plan::{CompiledPlan, PlanMode};
use pfp::profiling::Profiler;
use pfp::tensor::Tensor;
use pfp::util::prop::Gen;

fn input(arch: &Arch, batch: usize, seed: u64) -> Tensor {
    let mut g = Gen::new(seed);
    let n = batch * arch.input_len();
    Tensor::new(
        vec![batch, arch.input_len()],
        (0..n).map(|_| g.f32_in(0.0, 1.0)).collect(),
    )
    .unwrap()
}

#[test]
fn plan_matches_interpreter_bitwise_across_batches() {
    // Same kernels, same order, same serial schedules: the lowering must
    // be a pure reshuffling of *where* work happens, not *what* runs.
    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = PosteriorWeights::synthetic(&arch, 21);
        for batch in [1usize, 3, 10] {
            let x = input(&arch, batch, batch as u64);
            let (mu_i, var_i) =
                PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1))
                    .forward_interpreted(&x);
            let (mu_p, var_p) =
                PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1))
                    .forward(&x);
            assert_eq!(mu_i.data(), mu_p.data(), "{} b{batch} mu", arch.name);
            assert_eq!(var_i.data(), var_p.data(), "{} b{batch} var", arch.name);
        }
    }
}

#[test]
fn planned_parallel_bit_identical_to_serial_and_interpreter() {
    // The tentpole determinism guarantee: work is partitioned over rows
    // (never over the reduction), so planned-parallel == planned-serial
    // == forward_interpreted, bit for bit, at every thread count.
    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = PosteriorWeights::synthetic(&arch, 31);
        for batch in [1usize, 4, 10] {
            let x = input(&arch, batch, 100 + batch as u64);
            let (mu_i, var_i) =
                PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1))
                    .forward_interpreted(&x);
            let (mu_s, var_s) =
                PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1))
                    .forward(&x);
            assert_eq!(mu_i.data(), mu_s.data(), "{} b{batch} serial mu", arch.name);
            assert_eq!(var_i.data(), var_s.data(), "{} b{batch} serial var", arch.name);
            for t in [2usize, 3, 4, 8] {
                let (mu_p, var_p) = PfpExecutor::new(
                    arch.clone(),
                    weights.clone(),
                    Schedules::tuned(1).with_plan_threads(t),
                )
                .forward(&x);
                assert_eq!(
                    mu_s.data(),
                    mu_p.data(),
                    "{} b{batch} t{t} mu diverged from serial",
                    arch.name
                );
                assert_eq!(
                    var_s.data(),
                    var_p.data(),
                    "{} b{batch} t{t} var diverged from serial",
                    arch.name
                );
            }
        }
    }
}

#[test]
fn planned_parallel_tiled_schedules_bit_identical_across_tile_counts() {
    // Cache-blocked (tiled) schedules are admitted into plan lowering;
    // within one schedule, the parallel partition must still not change a
    // bit vs plan_threads = 1 (tile_k changes the reduction *grouping*,
    // which is why the comparison baseline carries the same schedule).
    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = PosteriorWeights::synthetic(&arch, 32);
        let x = input(&arch, 6, 41);
        let mut tiled = Schedules::tuned(1);
        tiled.dense = Schedule::tuned(1).with_tiles(16, 32);
        tiled.conv = Schedule::tuned(1).with_tiles(8, 64);
        let (mu_s, var_s) = PfpExecutor::new(
            arch.clone(),
            weights.clone(),
            tiled.clone().with_plan_threads(1),
        )
        .forward(&x);
        for t in [2usize, 5] {
            let (mu_p, var_p) = PfpExecutor::new(
                arch.clone(),
                weights.clone(),
                tiled.clone().with_plan_threads(t),
            )
            .forward(&x);
            assert_eq!(mu_s.data(), mu_p.data(), "{} t{t} tiled mu", arch.name);
            assert_eq!(var_s.data(), var_p.data(), "{} t{t} tiled var", arch.name);
        }
    }
}

#[test]
fn det_plan_parallel_matches_serial() {
    use pfp::model::DetExecutor;
    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = PosteriorWeights::synthetic(&arch, 33);
        let x = input(&arch, 5, 51);
        let serial = DetExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1))
            .forward(&x);
        let par = DetExecutor::new(
            arch.clone(),
            weights.clone(),
            Schedules::tuned(1).with_plan_threads(4),
        )
        .forward(&x);
        assert_eq!(serial.data(), par.data(), "{} det parallel", arch.name);
    }
}

#[test]
fn plan_parity_holds_for_baseline_schedules_too() {
    // generic pool + Mkn loop order exercise the non-default step kinds
    let arch = Arch::lenet();
    let weights = PosteriorWeights::synthetic(&arch, 22);
    let x = input(&arch, 2, 7);
    let (mu_i, var_i) =
        PfpExecutor::new(arch.clone(), weights.clone(), Schedules::baseline())
            .forward_interpreted(&x);
    let (mu_p, var_p) =
        PfpExecutor::new(arch.clone(), weights, Schedules::baseline()).forward(&x);
    assert_eq!(mu_i.data(), mu_p.data());
    assert_eq!(var_i.data(), var_p.data());
}

#[test]
fn workspace_reuse_is_deterministic() {
    // second execute() on the same workspace must be bit-identical to the
    // first: no state may leak between calls
    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = Arc::new(PosteriorWeights::synthetic(&arch, 23));
        let plan = CompiledPlan::compile(
            &arch,
            Arc::clone(&weights),
            &Schedules::tuned(1),
            4,
            PlanMode::Pfp,
        )
        .unwrap();
        let mut ws = plan.workspace();
        let x = input(&arch, 4, 11);
        let mut off = Profiler::new(false);
        let first = {
            let (mu, var) = plan.execute(x.data(), &mut ws, &mut off);
            (mu.to_vec(), var.to_vec())
        };
        // interleave a different input to dirty every buffer...
        let other = input(&arch, 4, 12);
        let _ = plan.execute(other.data(), &mut ws, &mut off);
        // ...then re-run the original
        let (mu2, var2) = plan.execute(x.data(), &mut ws, &mut off);
        assert_eq!(first.0.as_slice(), mu2, "{} mu drifted", arch.name);
        assert_eq!(first.1.as_slice(), var2, "{} var drifted", arch.name);
    }
}

#[test]
fn per_layer_schedule_table_agrees_within_tolerances() {
    // a fully heterogeneous table (every layer different) must agree with
    // the uniform schedule within the repo's established tolerances
    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = PosteriorWeights::synthetic(&arch, 24);
        let x = input(&arch, 3, 13);
        let (mu_u, var_u) =
            PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1))
                .forward(&x);
        let variants = [
            Schedule::tuned(1),
            Schedule::tuned(1).with_unroll(4),
            Schedule::tiled(16, 64),
            Schedule::tuned(2),
            Schedule::baseline(),
        ];
        let mut sched = Schedules::tuned(1);
        for i in 0..arch.compute_layers().len() {
            sched = sched.with_layer_schedule(i, variants[i % variants.len()]);
        }
        let (mu_o, var_o) = PfpExecutor::new(arch.clone(), weights, sched).forward(&x);
        assert!(mu_u.allclose(&mu_o, 1e-4, 1e-4), "{} mu", arch.name);
        assert!(var_u.allclose(&var_o, 2e-3, 2e-3), "{} var", arch.name);
    }
}

fn plan_service(max_batch: usize) -> Service {
    let mut cfg = ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
    cfg.batcher.max_batch = max_batch;
    let mut svc = Service::new(cfg);
    let arch = Arch::mlp();
    let weights = PosteriorWeights::synthetic(&arch, 25);
    svc.register(
        "mlp",
        784,
        Box::new(NativePfpBackend::new(arch, weights, Schedules::tuned(1))),
    );
    svc
}

fn plan_compiles(svc: &Service) -> u64 {
    svc.metrics
        .plan_compiles
        .load(std::sync::atomic::Ordering::Relaxed)
}

#[test]
fn service_serves_repeat_buckets_from_cached_plans() {
    let svc = plan_service(4);
    // sequential blocking requests: every forward pass runs at batch 1
    for i in 0..6u64 {
        let resp = svc.infer_blocking(protocol::Request {
            id: i,
            model: "mlp".into(),
            input: vec![0.3; 784],
        });
        assert!(resp.result.is_ok());
    }
    assert_eq!(
        plan_compiles(&svc),
        1,
        "six batch-1 passes must share one cold compile"
    );
}

#[test]
fn service_plan_cache_bounded_by_bucket_sizes() {
    let svc = plan_service(4);
    // mixed burst + blocking traffic: the dynamic batcher may form any
    // bucket size in 1..=4, each compiled at most once
    for round in 0..3u64 {
        let (tx, rx) = channel();
        for i in 0..8u64 {
            svc.submit_with(
                protocol::Request {
                    id: round * 100 + i,
                    model: "mlp".into(),
                    input: vec![0.1 * (i % 7) as f32; 784],
                },
                tx.clone(),
            )
            .expect("submit");
        }
        drop(tx);
        assert_eq!(rx.iter().filter(|r| r.result.is_ok()).count(), 8);
    }
    let compiles = plan_compiles(&svc);
    assert!(
        (1u64..=4).contains(&compiles),
        "cold compiles ({compiles}) must be bounded by the bucket sizes, not the request count (24)"
    );
}
