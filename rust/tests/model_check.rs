//! Full-size model-checker runs (`cargo test --features model_check`,
//! or `make model-check`). Tier-1 already explores fast configurations
//! of every model; this suite pushes the state spaces to the sizes the
//! acceptance bar names — ≤3 virtual threads, exhaustive, zero
//! violations, mutant corpus detected — and prints exploration sizes so
//! the CI log shows what "exhaustive" meant.
#![cfg(feature = "model_check")]

use pfp::verify::models::broadcast::{Broadcast, Nested};
use pfp::verify::models::lazygrow::LazyGrow;
use pfp::verify::models::swapdrain::SwapDrain;
use pfp::verify::{Checker, Model, Report};

fn explore<M: Model>(name: &str, model: &M) -> Report {
    let report = Checker::default().run(model);
    println!(
        "model-check: {name}: {} states, {} transitions, exhaustive = {}, violation = {:?}",
        report.states, report.transitions, report.exhaustive, report.violation
    );
    report
}

#[test]
fn broadcast_exhaustive_at_three_threads() {
    for n_tasks in 1..=3 {
        let report = explore(
            &format!("broadcast 1L+2W x{n_tasks}"),
            &Broadcast::leader_and_workers(2, n_tasks),
        );
        assert!(report.passed(), "n_tasks = {n_tasks}: {:?}", report.violation);
    }
}

#[test]
fn broadcast_competing_leaders_exhaustive() {
    for n_tasks in 2..=3 {
        let report = explore(
            &format!("broadcast 2L+1W x{n_tasks}"),
            &Broadcast::competing_leaders(n_tasks),
        );
        assert!(report.passed(), "n_tasks = {n_tasks}: {:?}", report.violation);
    }
}

#[test]
fn broadcast_nested_inline_exhaustive() {
    let report = explore(
        "broadcast nested-inline",
        &Broadcast::leader_and_workers(2, 3).with_nested(Nested::Inline),
    );
    assert!(report.passed(), "{:?}", report.violation);
}

#[test]
fn lazygrow_exhaustive() {
    for (jobs, cap) in [(2, 2), (3, 2), (3, 1), (0, 2)] {
        let report = explore(&format!("lazygrow j{jobs} c{cap}"), &LazyGrow::new(jobs, cap));
        assert!(report.passed(), "jobs = {jobs}, cap = {cap}: {:?}", report.violation);
    }
}

#[test]
fn swapdrain_exhaustive() {
    for requesters in 1..=2 {
        let report = explore(&format!("swapdrain r{requesters}"), &SwapDrain::new(requesters));
        assert!(report.passed(), "requesters = {requesters}: {:?}", report.violation);
    }
}

#[test]
fn mutant_corpus_is_detected() {
    // Every seeded bug must be found — the checker is proven able to
    // fail, not just pass.
    let lost_notify =
        explore("mutant lost-notify", &Broadcast::leader_and_workers(2, 2).with_lost_notify());
    assert!(
        lost_notify.violation.expect("lost-notify must be found").message.contains("deadlock"),
        "lost-notify mutant"
    );

    let nested = explore(
        "mutant nested-blocking",
        &Broadcast::leader_and_workers(2, 2).with_nested(Nested::Blocking),
    );
    assert!(nested.violation.is_some(), "guard-less nested re-entry must be found");

    let lost_submit = explore("mutant lost-submit-notify", &LazyGrow::new(2, 2).with_lost_notify());
    assert!(lost_submit.violation.is_some(), "lost submit notify must be found");

    let split_pin = explore("mutant split-pin", &SwapDrain::new(2).with_split_pin());
    assert!(split_pin.violation.is_some(), "split pin TOCTOU must be found");
}

#[test]
fn violations_replay_deterministically() {
    // The schedule in a violation is a real witness: replaying it step
    // by step from init reproduces the stuck state.
    let model = Broadcast::leader_and_workers(2, 2).with_lost_notify();
    let v = Checker::default().run(&model).violation.expect("mutant violation");
    let mut s = model.init();
    for &tid in &v.schedule {
        assert!(model.enabled(&s, tid), "witness schedule step not enabled");
        model.step(&mut s, tid).expect("witness prefix steps are violation-free");
    }
    // end of witness: the deadlock state — nobody enabled, not all done
    let n = model.threads();
    assert!((0..n).all(|t| !model.enabled(&s, t)));
    assert!((0..n).any(|t| !model.done(&s, t)));
}
