//! Cross-language correctness: the native Rust operator stack vs the JAX
//! reference stack, on the *trained* weights and real test inputs.
//!
//! The goldens (`artifacts/goldens.npz`) are produced by
//! `python/compile/aot.py`: for each (arch, variant, batch) entry, an
//! input slice of the test set plus the JAX outputs. These tests require
//! `make artifacts`; they skip (with a notice) when artifacts are absent.
//!
//! ## Tolerance policy (SIMD dispatch)
//!
//! The PFP goldens are checked on **both** dispatch paths: the tuned
//! schedules' native ISA (runtime-detected AVX2+FMA / NEON) and the
//! forced-scalar path (`--isa scalar` semantics; CI additionally runs the
//! whole suite under `PFP_FORCE_SCALAR=1`). The layered contract:
//!
//! * within one ISA, planned == interpreted == planned-parallel **bit for
//!   bit** (asserted below on the trained posterior);
//! * across ISAs, outputs differ by <= 1e-4 relative (FMA reassociation
//!   plus the vectorized exp/erf polynomials, each ~1e-6 absolute —
//!   `ops/erf.rs` pins those bounds against an f64 reference table);
//! * both ISAs therefore land inside the JAX-golden envelope (2e-3 mlp /
//!   5e-3 lenet — dominated by f32-vs-f64 and training-artifact noise,
//!   not by the ISA choice).

use pfp::model::npz::Npz;
use pfp::model::{Arch, DetExecutor, FusePolicy, PfpExecutor, PosteriorWeights, Schedules};
use pfp::ops::simd::Isa;
use pfp::runtime::Manifest;
use pfp::tensor::Tensor;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pfp::artifacts_dir();
    if dir.join("goldens.npz").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn load_weights(dir: &std::path::Path, arch: &Arch) -> (PosteriorWeights, f32) {
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let calib = manifest.calibration_factor(&arch.name);
    (
        PosteriorWeights::load(dir, arch, calib).unwrap(),
        calib,
    )
}

fn check_pfp(arch_name: &str, batch: usize, atol: f32) {
    let Some(dir) = artifacts() else { return };
    let goldens = Npz::open(&dir.join("goldens.npz")).unwrap();
    let arch = Arch::by_name(arch_name).unwrap();
    let (weights, _) = load_weights(&dir, &arch);
    let key = format!("model_{arch_name}_pfp_b{batch}");
    let x = goldens.tensor(&format!("{key}_x")).unwrap();
    let want_mu = goldens.tensor(&format!("{key}_mu")).unwrap();
    let want_var = goldens.tensor(&format!("{key}_var")).unwrap();

    let x2d = x.clone().flatten_2d();
    // both dispatch paths must sit inside the golden envelope, with
    // epilogue fusion forced off AND on (fused == unfused bit for bit at
    // one ISA, so both land identically — asserted rather than assumed;
    // see the tolerance policy in the file header)
    for isa_override in [None, Some(Isa::Scalar)] {
        for fuse in [FusePolicy::Off, FusePolicy::On] {
            let schedules = Schedules::tuned(1)
                .with_isa_override(isa_override)
                .with_fuse(fuse);
            let mut exec = PfpExecutor::new(arch.clone(), weights.clone(), schedules);
            let (mu, var) = exec.forward(&x2d);
            let isa_tag = match isa_override {
                None => "native",
                Some(_) => "scalar",
            };
            assert!(
                mu.allclose(&want_mu.clone().flatten_2d(), atol, 1e-3),
                "{key} [{isa_tag} {fuse:?}]: mu deviates from JAX golden (max {:.2e})",
                mu.max_abs_diff(&want_mu.clone().flatten_2d())
            );
            assert!(
                var.allclose(&want_var.clone().flatten_2d(), atol * 2.0, 5e-3),
                "{key} [{isa_tag} {fuse:?}]: var deviates from JAX golden (max {:.2e})",
                var.max_abs_diff(&want_var.clone().flatten_2d())
            );
        }
    }
}

#[test]
fn native_pfp_mlp_matches_jax_golden_b1() {
    check_pfp("mlp", 1, 2e-3);
}

#[test]
fn native_pfp_mlp_matches_jax_golden_b10() {
    check_pfp("mlp", 10, 2e-3);
}

#[test]
fn native_pfp_mlp_matches_jax_golden_b100() {
    check_pfp("mlp", 100, 2e-3);
}

#[test]
fn native_pfp_lenet_matches_jax_golden_b1() {
    check_pfp("lenet", 1, 5e-3);
}

#[test]
fn native_pfp_lenet_matches_jax_golden_b10() {
    check_pfp("lenet", 10, 5e-3);
}

#[test]
fn compiled_plan_matches_interpreter_on_goldens() {
    // On the *trained* posterior and real test inputs (not synthetic
    // weights), the compiled plan must reproduce the interpretive
    // executor bit for bit — and therefore inherit its golden match.
    let Some(dir) = artifacts() else { return };
    let goldens = Npz::open(&dir.join("goldens.npz")).unwrap();
    for (arch_name, batch) in [("mlp", 10), ("lenet", 10)] {
        let arch = Arch::by_name(arch_name).unwrap();
        let (weights, _) = load_weights(&dir, &arch);
        let key = format!("model_{arch_name}_pfp_b{batch}");
        let x = goldens
            .tensor(&format!("{key}_x"))
            .unwrap()
            .flatten_2d();
        let (mu_i, var_i) =
            PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1))
                .forward_interpreted(&x);
        let (mu_p, var_p) =
            PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1))
                .forward(&x);
        assert_eq!(mu_i.data(), mu_p.data(), "{key}: plan mu != interpreter mu");
        assert_eq!(var_i.data(), var_p.data(), "{key}: plan var != interpreter var");
        // planned-parallel inherits the golden match too: row-partitioned
        // tiles must be bit-identical at every thread count
        for t in [2usize, 4] {
            let (mu_t, var_t) = PfpExecutor::new(
                arch.clone(),
                weights.clone(),
                Schedules::tuned(1).with_plan_threads(t),
            )
            .forward(&x);
            assert_eq!(mu_p.data(), mu_t.data(), "{key}: t{t} plan mu diverged");
            assert_eq!(var_p.data(), var_t.data(), "{key}: t{t} plan var diverged");
        }
    }
}

#[test]
fn native_det_matches_jax_golden() {
    let Some(dir) = artifacts() else { return };
    let goldens = Npz::open(&dir.join("goldens.npz")).unwrap();
    for arch_name in ["mlp", "lenet"] {
        let arch = Arch::by_name(arch_name).unwrap();
        let (weights, _) = load_weights(&dir, &arch);
        let key = format!("model_{arch_name}_det_b10");
        let x = goldens.tensor(&format!("{key}_x")).unwrap().flatten_2d();
        let want = goldens
            .tensor(&format!("{key}_logits"))
            .unwrap()
            .flatten_2d();
        let exec = DetExecutor::new(arch, weights, Schedules::tuned(1));
        let got = exec.forward(&x);
        assert!(
            got.allclose(&want, 1e-3, 1e-3),
            "{key}: det logits deviate (max {:.2e})",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn native_uncertainty_matches_python_metrics() {
    // The python pipeline stored PFP logit moments per split; recompute
    // MI/SME in Rust from the same moments and compare to the python
    // uncertainty arrays (same Eq. 11 protocol, different RNG -> compare
    // distribution means, not elementwise).
    let Some(dir) = artifacts() else { return };
    let unc = Npz::open(&dir.join("uncertainty_mlp.npz")).unwrap();
    for split in ["mnist", "ood"] {
        let mu = unc.tensor(&format!("pfp_{split}_logit_mu")).unwrap();
        let var = unc.tensor(&format!("pfp_{split}_logit_var")).unwrap();
        let u = pfp::uncertainty::pfp_uncertainty(&mu, &var, 30, 9);
        let py_mi = unc.tensor(&format!("pfp_{split}_mi")).unwrap();
        let rust_mean: f64 = u.mi.iter().sum::<f64>() / u.mi.len() as f64;
        let py_mean: f64 =
            py_mi.data().iter().map(|&v| v as f64).sum::<f64>() / py_mi.len() as f64;
        assert!(
            (rust_mean - py_mean).abs() < 0.05 + 0.2 * py_mean.abs(),
            "{split}: rust MI mean {rust_mean} vs python {py_mean}"
        );
    }
}

#[test]
fn golden_input_shapes_consistent() {
    let Some(dir) = artifacts() else { return };
    let goldens = Npz::open(&dir.join("goldens.npz")).unwrap();
    let x = goldens.tensor("model_mlp_pfp_b10_x").unwrap();
    assert_eq!(x.shape(), &[10, 784]);
    let x = goldens.tensor("model_lenet_pfp_b10_x").unwrap();
    assert_eq!(x.shape(), &[10, 1, 28, 28]);
    let _ = Tensor::zeros(vec![1]);
}
