//! Differential property-test harness for the SIMD microkernel layer —
//! randomized cross-backend parity under an explicit tolerance contract.
//!
//! The contract being policed (documented in `ops/simd.rs`):
//!
//! * **bit-identical within one ISA at a fixed thread/tile count** — and,
//!   because plan partitioning never splits a reduction, bit-identical
//!   *across* tile counts {1, 2, 4} within one ISA too;
//! * **<= 1e-4 relative across ISAs** — FMA contraction reassociates the
//!   dense reductions, and the vector `exp` is a polynomial, so scalar
//!   and native outputs are close, not equal (under `PFP_FORCE_SCALAR=1`
//!   native resolves to scalar and the cross-ISA checks become exact —
//!   the CI dispatch matrix runs both branches);
//! * planned and interpreted execution agree bit for bit at the same ISA.
//!
//! The fused-epilogue contract (PR 8) is policed here too: a fused
//! dense/conv step (ReLU, or ReLU + E2→Var convert, applied on the
//! register/cache-resident output tile) is **bit-identical to the unfused
//! chain at the same ISA**, kernel-level and whole-network, across random
//! schedules, batches, and thread counts.
//!
//! The mixed-precision contract (PR 9) is policed here too, at two
//! tiers. Tier 1, exact: a packed-operand kernel (f16/bf16 weight
//! storage, f32 accumulation) is **bit-identical, per ISA and tile
//! count, to the plain f32 kernel run on weights widened from the same
//! u16 storage bits** — packing only moves where the bits live, every
//! arithmetic op stays f32, so the tolerance is zero. Tier 2, bounded:
//! a whole network served packed tracks the f32 network within a coarse
//! envelope (rtol 0.15 / atol 0.1 on the logit moments — the RNE
//! quantization error of <=0.4% per bf16 value compounds through the
//! layers but stays far inside this bound in practice); the statistically
//! meaningful accuracy/ECE/AUROC budget lives in
//! `integration_precision_cert.rs`.
//!
//! Shapes, schedules (every knob, ISA included), and inputs are drawn
//! from the seeded [`prop::check`] harness, which prints the failing case
//! seed (`PFP_PROP_SEED=<base>, case seed <s>`) so any failure replays
//! exactly.

use pfp::model::{Arch, FusePolicy, PfpExecutor, PosteriorWeights, Schedules};
use pfp::ops::dense::{
    dense_kernel_packed_tiled_into, dense_kernel_tiled_into, dense_rows_into, DenseSlices,
    FirstLayer, JointEq12, PackedDenseSlices,
};
use pfp::ops::simd::PackedSlice;
use pfp::util::half::{narrow, widen, Precision};
use pfp::ops::maxpool::pfp_maxpool2_planes_into;
use pfp::ops::relu::{pfp_relu_rows_into, pfp_relu_tiled_into};
use pfp::ops::simd::Isa;
use pfp::ops::Epilogue;
use pfp::plan::tile_ranges;
use pfp::tensor::Tensor;
use pfp::util::prop::{check, Gen};
use pfp::util::threadpool::ThreadPool;

/// |a - b| <= atol + rtol * |b| per element, with the failing index named.
fn assert_close(tag: &str, got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "{tag}: length mismatch");
    for i in 0..got.len() {
        let (a, b) = (got[i], want[i]);
        assert!(
            (a - b).abs() <= atol + rtol * b.abs(),
            "{tag}: element {i}: {a} vs {b} (diff {})",
            (a - b).abs()
        );
    }
}

fn rand_dense_case(
    g: &mut Gen,
    m: usize,
    k: usize,
    n: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let x_mu = g.normal_vec(m * k, 1.0);
    let x_e2: Vec<f32> = x_mu.iter().map(|&v| v * v + 0.1).collect();
    let w_mu = g.normal_vec(n * k, 0.2);
    let w_e2: Vec<f32> = w_mu.iter().map(|&v| v * v + 0.01).collect();
    let b_mu = g.normal_vec(n, 0.5);
    let b_var = g.var_vec(n, 0.1);
    (x_mu, x_e2, w_mu, w_e2, b_mu, b_var)
}

#[test]
fn dense_randomized_cross_isa_and_tile_parity() {
    let pool = ThreadPool::new(4);
    check(20, |g| {
        let (m, k, n) = g.dense_shape(10, 130, 40);
        let sched = g.schedule();
        let (x_mu, x_e2, w_mu, w_e2, b_mu, b_var) = rand_dense_case(g, m, k, n);
        let slices = DenseSlices {
            m,
            k,
            n,
            x_mu: &x_mu,
            x_aux: &x_e2,
            w_mu: &w_mu,
            w_aux: &w_e2,
            b_mu: Some(&b_mu),
            b_var: Some(&b_var),
        };
        let mut outs: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
        for isa in [Isa::Scalar, Isa::Native] {
            let s = sched.with_isa(isa);
            // serial reference for this ISA
            let mut want_mu = vec![0.0f32; m * n];
            let mut want_var = vec![0.0f32; m * n];
            dense_rows_into::<JointEq12>(
                &slices, &s, Epilogue::None, 0..m, &mut want_mu, &mut want_var,
            );
            // thread/tile counts {1, 2, 4}: bit-identical within the ISA
            for tasks in [1usize, 2, 4] {
                let tiles = tile_ranges(m, tasks);
                let mut mu = vec![0.0f32; m * n];
                let mut var = vec![0.0f32; m * n];
                dense_kernel_tiled_into::<JointEq12>(
                    &pool, &slices, &s, Epilogue::None, &tiles, &mut mu, &mut var,
                );
                assert_eq!(mu, want_mu, "{} [{m},{k},{n}] tasks={tasks} mu", s.tag());
                assert_eq!(var, want_var, "{} [{m},{k},{n}] tasks={tasks} var", s.tag());
            }
            outs.push((want_mu, want_var));
        }
        // across ISAs: the 1e-4-relative contract
        let tag = format!("{} [{m},{k},{n}]", sched.tag());
        assert_close(&format!("{tag} mu"), &outs[1].0, &outs[0].0, 1e-4, 1e-4);
        assert_close(&format!("{tag} var"), &outs[1].1, &outs[0].1, 1e-3, 1e-4);
    });
}

#[test]
fn first_layer_randomized_cross_isa_parity() {
    check(12, |g| {
        let (m, k, n) = g.dense_shape(6, 100, 24);
        let sched = g.schedule();
        let x = g.normal_vec(m * k, 1.0);
        let x_sq: Vec<f32> = x.iter().map(|&v| v * v).collect();
        let w_mu = g.normal_vec(n * k, 0.2);
        let w_var = g.var_vec(n * k, 0.02);
        let slices = DenseSlices {
            m,
            k,
            n,
            x_mu: &x,
            x_aux: &x_sq,
            w_mu: &w_mu,
            w_aux: &w_var,
            b_mu: None,
            b_var: None,
        };
        let mut mu_s = vec![0.0f32; m * n];
        let mut var_s = vec![0.0f32; m * n];
        let mut mu_n = vec![0.0f32; m * n];
        let mut var_n = vec![0.0f32; m * n];
        dense_rows_into::<FirstLayer>(
            &slices,
            &sched.with_isa(Isa::Scalar),
            Epilogue::None,
            0..m,
            &mut mu_s,
            &mut var_s,
        );
        dense_rows_into::<FirstLayer>(
            &slices,
            &sched.with_isa(Isa::Native),
            Epilogue::None,
            0..m,
            &mut mu_n,
            &mut var_n,
        );
        let tag = format!("first {} [{m},{k},{n}]", sched.tag());
        assert_close(&format!("{tag} mu"), &mu_n, &mu_s, 1e-4, 1e-4);
        assert_close(&format!("{tag} var"), &var_n, &var_s, 1e-3, 1e-4);
    });
}

#[test]
fn relu_randomized_cross_isa_and_tile_parity() {
    let pool = ThreadPool::new(4);
    check(16, |g| {
        let n = g.usize_in(1, 600);
        let mu = g.normal_vec(n, 2.0);
        let var = g.var_vec(n, 1.0);
        let mut per_isa: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
        for isa in [Isa::Scalar, Isa::Native] {
            let mut want_mu = vec![0.0f32; n];
            let mut want_e2 = vec![0.0f32; n];
            pfp_relu_tiled_into(&pool, isa, &mu, &var, &[], &mut want_mu, &mut want_e2);
            for tasks in [2usize, 4] {
                let tiles = tile_ranges(n, tasks);
                let mut got_mu = vec![0.0f32; n];
                let mut got_e2 = vec![0.0f32; n];
                pfp_relu_tiled_into(&pool, isa, &mu, &var, &tiles, &mut got_mu, &mut got_e2);
                assert_eq!(got_mu, want_mu, "{isa:?} n={n} tasks={tasks} mu");
                assert_eq!(got_e2, want_e2, "{isa:?} n={n} tasks={tasks} e2");
            }
            per_isa.push((want_mu, want_e2));
        }
        assert_close(&format!("relu n={n} mu"), &per_isa[1].0, &per_isa[0].0, 1e-4, 1e-5);
        assert_close(&format!("relu n={n} e2"), &per_isa[1].1, &per_isa[0].1, 1e-4, 1e-5);
    });
}

#[test]
fn maxpool_randomized_cross_isa_parity() {
    check(12, |g| {
        let planes = g.usize_in(1, 6);
        let h = 2 * g.usize_in(1, 6);
        let w = 2 * g.usize_in(1, 9); // odd output widths hit the lane tail
        let mu = g.normal_vec(planes * h * w, 1.0);
        let var = g.var_vec(planes * h * w, 0.5);
        let out_len = planes * (h / 2) * (w / 2);
        let mut per_isa: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
        for isa in [Isa::Scalar, Isa::Native] {
            let mut out_mu = vec![0.0f32; out_len];
            let mut out_var = vec![0.0f32; out_len];
            pfp_maxpool2_planes_into(isa, &mu, &var, h, w, 0..planes, &mut out_mu, &mut out_var);
            per_isa.push((out_mu, out_var));
        }
        let tag = format!("pool [{planes}x{h}x{w}]");
        assert_close(&format!("{tag} mu"), &per_isa[1].0, &per_isa[0].0, 1e-4, 1e-5);
        assert_close(&format!("{tag} var"), &per_isa[1].1, &per_isa[0].1, 1e-3, 1e-4);
    });
}

#[test]
fn network_planned_interpreted_and_cross_isa_parity() {
    // whole-network differential: for each arch and random batch,
    //  * planned == interpreted bit for bit at the native ISA,
    //  * planned at plan_threads {2, 4} == planned serial bit for bit,
    //  * native vs forced-scalar within the 1e-4-relative contract.
    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = PosteriorWeights::synthetic(&arch, 31);
        check(3, |g| {
            let batch = g.usize_in(1, 5);
            let n = batch * arch.input_len();
            let x = Tensor::new(
                vec![batch, arch.input_len()],
                (0..n).map(|_| g.f32_in(0.0, 1.0)).collect(),
            )
            .unwrap();

            let (mu_i, var_i) =
                PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1))
                    .forward_interpreted(&x);
            let (mu_p, var_p) =
                PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1))
                    .forward(&x);
            assert_eq!(mu_i.data(), mu_p.data(), "{} b{batch} plan != interp mu", arch.name);
            assert_eq!(var_i.data(), var_p.data(), "{} b{batch} plan != interp var", arch.name);

            for t in [2usize, 4] {
                let (mu_t, var_t) = PfpExecutor::new(
                    arch.clone(),
                    weights.clone(),
                    Schedules::tuned(1).with_plan_threads(t),
                )
                .forward(&x);
                assert_eq!(mu_p.data(), mu_t.data(), "{} b{batch} t{t} mu", arch.name);
                assert_eq!(var_p.data(), var_t.data(), "{} b{batch} t{t} var", arch.name);
            }

            let (mu_s, var_s) = PfpExecutor::new(
                arch.clone(),
                weights.clone(),
                Schedules::tuned(1).with_isa_override(Some(Isa::Scalar)),
            )
            .forward(&x);
            let tag = format!("{} b{batch} native-vs-scalar", arch.name);
            assert_close(&format!("{tag} mu"), mu_p.data(), mu_s.data(), 1e-4, 1e-4);
            assert_close(&format!("{tag} var"), var_p.data(), var_s.data(), 1e-3, 1e-4);
        });
    }
}

#[test]
fn dense_fused_epilogue_randomized_parity() {
    // kernel-level fusion contract, over random shapes x schedules x
    // tile counts x ISAs: a dense kernel run with a fused epilogue is
    // bit-identical to the bare kernel followed by the standalone
    // relu(+convert) chain it replaces.
    let pool = ThreadPool::new(4);
    check(16, |g| {
        let (m, k, n) = g.dense_shape(8, 100, 32);
        let sched = g.schedule();
        let (x_mu, x_e2, w_mu, w_e2, b_mu, b_var) = rand_dense_case(g, m, k, n);
        let slices = DenseSlices {
            m,
            k,
            n,
            x_mu: &x_mu,
            x_aux: &x_e2,
            w_mu: &w_mu,
            w_aux: &w_e2,
            b_mu: Some(&b_mu),
            b_var: Some(&b_var),
        };
        for isa in [Isa::Scalar, Isa::Native] {
            let s = sched.with_isa(isa);
            // unfused reference: bare kernel, then standalone ReLU, then
            // the E2→Var conversion the executor's convert step applies
            let mut mu_u = vec![0.0f32; m * n];
            let mut var_u = vec![0.0f32; m * n];
            dense_rows_into::<JointEq12>(&slices, &s, Epilogue::None, 0..m, &mut mu_u, &mut var_u);
            let mut rm = vec![0.0f32; m * n];
            let mut re2 = vec![0.0f32; m * n];
            pfp_relu_rows_into(isa, &mu_u, &var_u, 0..m * n, &mut rm, &mut re2);
            let rvar: Vec<f32> = re2
                .iter()
                .zip(&rm)
                .map(|(&e2, &mv)| (e2 - mv * mv).max(0.0))
                .collect();
            let tag = format!("{} [{m},{k},{n}] {isa:?}", s.tag());
            for tasks in [1usize, 2, 4] {
                let tiles = tile_ranges(m, tasks);
                let mut mu_f = vec![0.0f32; m * n];
                let mut aux_f = vec![0.0f32; m * n];
                dense_kernel_tiled_into::<JointEq12>(
                    &pool, &slices, &s, Epilogue::Relu, &tiles, &mut mu_f, &mut aux_f,
                );
                assert_eq!(mu_f, rm, "{tag} tasks={tasks} fused relu mu");
                assert_eq!(aux_f, re2, "{tag} tasks={tasks} fused relu e2");
                dense_kernel_tiled_into::<JointEq12>(
                    &pool, &slices, &s, Epilogue::ReluToVar, &tiles, &mut mu_f, &mut aux_f,
                );
                assert_eq!(mu_f, rm, "{tag} tasks={tasks} fused relu+convert mu");
                assert_eq!(aux_f, rvar, "{tag} tasks={tasks} fused relu+convert var");
            }
        }
    });
}

#[test]
fn dense_packed_randomized_bit_parity_with_widened_reference() {
    // tier-1 mixed-precision contract: the packed kernel must equal the
    // plain f32 kernel run on weights widened from the same storage
    // bits, bit for bit, per ISA, across tile counts and epilogues —
    // including the split case where only one operand is packed
    // (independent mean/variance precision).
    let pool = ThreadPool::new(4);
    check(16, |g| {
        let (m, k, n) = g.dense_shape(8, 100, 32);
        let sched = g.schedule();
        let prec = if g.usize_in(0, 1) == 0 { Precision::F16 } else { Precision::Bf16 };
        let (x_mu, x_e2, w_mu, w_e2, b_mu, b_var) = rand_dense_case(g, m, k, n);
        let wm_bits: Vec<u16> = w_mu.iter().map(|&v| narrow(prec, v)).collect();
        let wa_bits: Vec<u16> = w_e2.iter().map(|&v| narrow(prec, v)).collect();
        let wm_wide: Vec<f32> = wm_bits.iter().map(|&b| widen(prec, b)).collect();
        let wa_wide: Vec<f32> = wa_bits.iter().map(|&b| widen(prec, b)).collect();
        for isa in [Isa::Scalar, Isa::Native] {
            let s = sched.with_isa(isa);
            for ep in [Epilogue::None, Epilogue::Relu, Epilogue::ReluToVar] {
                let tag = format!("{} [{m},{k},{n}] {prec} {isa:?} {ep:?}", s.tag());
                // f32 reference on widened copies of the stored bits
                let ref_slices = DenseSlices {
                    m,
                    k,
                    n,
                    x_mu: &x_mu,
                    x_aux: &x_e2,
                    w_mu: &wm_wide,
                    w_aux: &wa_wide,
                    b_mu: Some(&b_mu),
                    b_var: Some(&b_var),
                };
                let mut want_mu = vec![0.0f32; m * n];
                let mut want_var = vec![0.0f32; m * n];
                dense_rows_into::<JointEq12>(
                    &ref_slices, &s, ep, 0..m, &mut want_mu, &mut want_var,
                );
                let pslices = PackedDenseSlices {
                    m,
                    k,
                    n,
                    x_mu: &x_mu,
                    x_aux: &x_e2,
                    w_mu: PackedSlice::U16(prec, &wm_bits),
                    w_aux: PackedSlice::U16(prec, &wa_bits),
                    b_mu: Some(&b_mu),
                    b_var: Some(&b_var),
                };
                for tasks in [1usize, 2, 4] {
                    let tiles = tile_ranges(m, tasks);
                    let mut mu = vec![0.0f32; m * n];
                    let mut var = vec![0.0f32; m * n];
                    dense_kernel_packed_tiled_into::<JointEq12>(
                        &pool, &pslices, &s, ep, &tiles, &mut mu, &mut var,
                    );
                    assert_eq!(mu, want_mu, "{tag} tasks={tasks} mu");
                    assert_eq!(var, want_var, "{tag} tasks={tasks} var");
                }
            }
            // split precision (mean packed, variance kept f32): the F32
            // operand variant must match the plain kernel on
            // (widened mu, original aux) exactly
            let mixed_ref = DenseSlices {
                m,
                k,
                n,
                x_mu: &x_mu,
                x_aux: &x_e2,
                w_mu: &wm_wide,
                w_aux: &w_e2,
                b_mu: Some(&b_mu),
                b_var: Some(&b_var),
            };
            let mut want_mu = vec![0.0f32; m * n];
            let mut want_var = vec![0.0f32; m * n];
            dense_rows_into::<JointEq12>(
                &mixed_ref, &s, Epilogue::None, 0..m, &mut want_mu, &mut want_var,
            );
            let pslices = PackedDenseSlices {
                m,
                k,
                n,
                x_mu: &x_mu,
                x_aux: &x_e2,
                w_mu: PackedSlice::U16(prec, &wm_bits),
                w_aux: PackedSlice::F32(&w_e2),
                b_mu: Some(&b_mu),
                b_var: Some(&b_var),
            };
            let tiles = tile_ranges(m, 2);
            let mut mu = vec![0.0f32; m * n];
            let mut var = vec![0.0f32; m * n];
            dense_kernel_packed_tiled_into::<JointEq12>(
                &pool, &pslices, &s, Epilogue::None, &tiles, &mut mu, &mut var,
            );
            let tag = format!("{} [{m},{k},{n}] {prec} {isa:?} split", s.tag());
            assert_eq!(mu, want_mu, "{tag} mu");
            assert_eq!(var, want_var, "{tag} var");
        }
    });
}

#[test]
fn first_layer_packed_randomized_bit_parity() {
    // same zero-tolerance contract for the Eq. 13 first-layer kernel
    // (x · mu_w / x² · var_w), which the packed plan binds for layer 0
    let pool = ThreadPool::new(2);
    check(10, |g| {
        let (m, k, n) = g.dense_shape(6, 100, 24);
        let sched = g.schedule();
        let prec = if g.usize_in(0, 1) == 0 { Precision::F16 } else { Precision::Bf16 };
        let x = g.normal_vec(m * k, 1.0);
        let x_sq: Vec<f32> = x.iter().map(|&v| v * v).collect();
        let w_mu = g.normal_vec(n * k, 0.2);
        let w_var = g.var_vec(n * k, 0.02);
        let wm_bits: Vec<u16> = w_mu.iter().map(|&v| narrow(prec, v)).collect();
        let wv_bits: Vec<u16> = w_var.iter().map(|&v| narrow(prec, v)).collect();
        let wm_wide: Vec<f32> = wm_bits.iter().map(|&b| widen(prec, b)).collect();
        let wv_wide: Vec<f32> = wv_bits.iter().map(|&b| widen(prec, b)).collect();
        for isa in [Isa::Scalar, Isa::Native] {
            let s = sched.with_isa(isa);
            let ref_slices = DenseSlices {
                m,
                k,
                n,
                x_mu: &x,
                x_aux: &x_sq,
                w_mu: &wm_wide,
                w_aux: &wv_wide,
                b_mu: None,
                b_var: None,
            };
            let mut want_mu = vec![0.0f32; m * n];
            let mut want_var = vec![0.0f32; m * n];
            dense_rows_into::<FirstLayer>(
                &ref_slices, &s, Epilogue::None, 0..m, &mut want_mu, &mut want_var,
            );
            let pslices = PackedDenseSlices {
                m,
                k,
                n,
                x_mu: &x,
                x_aux: &x_sq,
                w_mu: PackedSlice::U16(prec, &wm_bits),
                w_aux: PackedSlice::U16(prec, &wv_bits),
                b_mu: None,
                b_var: None,
            };
            for tasks in [1usize, 2] {
                let tiles = tile_ranges(m, tasks);
                let mut mu = vec![0.0f32; m * n];
                let mut var = vec![0.0f32; m * n];
                dense_kernel_packed_tiled_into::<FirstLayer>(
                    &pool, &pslices, &s, Epilogue::None, &tiles, &mut mu, &mut var,
                );
                let tag = format!("first {} [{m},{k},{n}] {prec} {isa:?}", s.tag());
                assert_eq!(mu, want_mu, "{tag} tasks={tasks} mu");
                assert_eq!(var, want_var, "{tag} tasks={tasks} var");
            }
        }
    });
}

#[test]
fn network_packed_randomized_parity() {
    // tier-2 whole-network contract: a packed plan (weights AND
    // inter-layer activations stored f16/bf16) is deterministic across
    // plan thread counts and tracks the f32 network within the coarse
    // envelope documented in the module header. Covers both archs, so
    // the conv packed kernel and the maxpool/relu round-trips are in.
    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = PosteriorWeights::synthetic(&arch, 53);
        check(2, |g| {
            let batch = g.usize_in(1, 4);
            let n = batch * arch.input_len();
            let x = Tensor::new(
                vec![batch, arch.input_len()],
                (0..n).map(|_| g.f32_in(0.0, 1.0)).collect(),
            )
            .unwrap();
            let (mu_32, var_32) =
                PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1))
                    .forward(&x);
            for prec in [Precision::F16, Precision::Bf16] {
                let (mu_p, var_p) = PfpExecutor::new(
                    arch.clone(),
                    weights.clone(),
                    Schedules::tuned(1).with_precision_override(Some(prec)),
                )
                .forward(&x);
                for t in [2usize, 4] {
                    let (mu_t, var_t) = PfpExecutor::new(
                        arch.clone(),
                        weights.clone(),
                        Schedules::tuned(1)
                            .with_precision_override(Some(prec))
                            .with_plan_threads(t),
                    )
                    .forward(&x);
                    let tag = format!("{} b{batch} {prec} t{t}", arch.name);
                    assert_eq!(mu_p.data(), mu_t.data(), "{tag} mu");
                    assert_eq!(var_p.data(), var_t.data(), "{tag} var");
                }
                let tag = format!("{} b{batch} {prec} packed-vs-f32", arch.name);
                assert_close(&format!("{tag} mu"), mu_p.data(), mu_32.data(), 0.15, 0.1);
                assert_close(&format!("{tag} var"), var_p.data(), var_32.data(), 0.15, 0.1);
            }
        });
    }
}

#[test]
fn fused_vs_unfused_randomized_network_parity() {
    // whole-network fusion contract: a plan compiled with every fusable
    // pattern fused (`FusePolicy::On`) matches the fully unfused plan
    // (`FusePolicy::Off`) BIT-IDENTICALLY at the same ISA — the fused
    // epilogue runs the same kernels on the same values, it only skips
    // the intermediate buffer round trip — across random batches, both
    // archs, both ISAs, and plan thread counts {1, 2, 4}.
    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = PosteriorWeights::synthetic(&arch, 77);
        check(3, |g| {
            let batch = g.usize_in(1, 5);
            let n = batch * arch.input_len();
            let x = Tensor::new(
                vec![batch, arch.input_len()],
                (0..n).map(|_| g.f32_in(0.0, 1.0)).collect(),
            )
            .unwrap();
            for isa in [None, Some(Isa::Scalar)] {
                let (mu_u, var_u) = PfpExecutor::new(
                    arch.clone(),
                    weights.clone(),
                    Schedules::tuned(1)
                        .with_isa_override(isa)
                        .with_fuse(FusePolicy::Off),
                )
                .forward(&x);
                for t in [1usize, 2, 4] {
                    let (mu_f, var_f) = PfpExecutor::new(
                        arch.clone(),
                        weights.clone(),
                        Schedules::tuned(1)
                            .with_isa_override(isa)
                            .with_fuse(FusePolicy::On)
                            .with_plan_threads(t),
                    )
                    .forward(&x);
                    let tag = format!("{} b{batch} {isa:?} t{t} fused-vs-unfused", arch.name);
                    assert_eq!(mu_u.data(), mu_f.data(), "{tag} mu");
                    assert_eq!(var_u.data(), var_f.data(), "{tag} var");
                }
            }
        });
    }
}
