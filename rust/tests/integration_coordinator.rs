//! Coordinator end-to-end: TCP server + native PFP backend on trained
//! weights, driven by real synthetic Dirty-MNIST images — in-domain
//! requests must come back confident, OOD requests flagged.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use pfp::coordinator::{protocol, NativePfpBackend, Server, ServerConfig, Service};
use pfp::data::DirtyMnist;
use pfp::model::{Arch, PosteriorWeights, Schedules};
use pfp::runtime::Manifest;

fn trained_service() -> Option<(Service, DirtyMnist, f64)> {
    let dir = pfp::artifacts_dir();
    if !dir.join("weights_mlp.npz").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let arch = Arch::mlp();
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let calib = manifest.calibration_factor("mlp");
    let weights = PosteriorWeights::load(&dir, &arch, calib).unwrap();
    let data = DirtyMnist::load(&dir).unwrap();

    // calibrate the OOD threshold: midpoint between mean in-domain and
    // mean OOD MI on a small calibration slice
    let mut exec =
        pfp::model::PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1));
    let (mu_in, var_in) = exec.forward(&data.test_mnist.x.first_rows(64));
    let (mu_ood, var_ood) = exec.forward(&data.test_ood.x.first_rows(64));
    let u_in = pfp::uncertainty::pfp_uncertainty(&mu_in, &var_in, 30, 1);
    let u_ood = pfp::uncertainty::pfp_uncertainty(&mu_ood, &var_ood, 30, 1);
    let m_in = u_in.mi.iter().sum::<f64>() / 64.0;
    let m_ood = u_ood.mi.iter().sum::<f64>() / 64.0;
    let threshold = 0.5 * (m_in + m_ood);

    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        ood_threshold: threshold,
        ..Default::default()
    };
    let mut svc = Service::new(cfg);
    svc.register(
        "mlp",
        784,
        Box::new(NativePfpBackend::new(arch, weights, Schedules::tuned(1))),
    );
    Some((svc, data, threshold))
}

#[test]
fn in_process_indomain_vs_ood() {
    let Some((svc, data, _)) = trained_service() else { return };
    let n = 40;
    let mut ood_flags_in = 0;
    let mut ood_flags_ood = 0;
    let mut correct = 0;
    for i in 0..n {
        let resp = svc.infer_blocking(protocol::Request {
            id: i as u64,
            model: "mlp".into(),
            input: data.test_mnist.x.row(i).to_vec(),
        });
        let p = resp.result.unwrap();
        if p.pred == data.test_mnist.y[i] {
            correct += 1;
        }
        ood_flags_in += p.ood as usize;
    }
    for i in 0..n {
        let resp = svc.infer_blocking(protocol::Request {
            id: (n + i) as u64,
            model: "mlp".into(),
            input: data.test_ood.x.row(i).to_vec(),
        });
        ood_flags_ood += resp.result.unwrap().ood as usize;
    }
    assert!(correct as f64 / n as f64 > 0.9, "accuracy {correct}/{n}");
    assert!(
        ood_flags_ood > ood_flags_in,
        "OOD flagging failed: in={ood_flags_in} ood={ood_flags_ood}"
    );
}

#[test]
fn tcp_roundtrip_with_metrics() {
    let Some((svc, data, _)) = trained_service() else { return };
    let svc = Arc::new(svc);
    let server = Server::bind(svc.clone()).unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.run());

    let stream = TcpStream::connect(addr).unwrap();
            stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // ping
    writeln!(writer, r#"{{"cmd":"ping"}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("pong"));

    // a few inferences
    for i in 0..5 {
        let req = protocol::request_json(i, "mlp", data.test_mnist.x.row(i as usize));
        writeln!(writer, "{req}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = protocol::Response::parse(line.trim()).unwrap();
        assert_eq!(resp.id, i);
        let p = resp.result.expect("inference ok");
        assert_eq!(p.mu.len(), 10);
        assert!(p.total >= 0.0 && p.sme >= 0.0 && p.mi >= 0.0);
    }

    // metrics
    writeln!(writer, r#"{{"cmd":"metrics"}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let m = pfp::util::json::Json::parse(line.trim()).unwrap();
    assert!(m.num_field("responses").unwrap() >= 5.0);
    assert!(m.num_field("latency_p50_us").unwrap() > 0.0);
}

#[test]
fn malformed_requests_do_not_kill_connection() {
    let Some((svc, data, _)) = trained_service() else { return };
    let svc = Arc::new(svc);
    let server = Server::bind(svc).unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.run());

    let stream = TcpStream::connect(addr).unwrap();
            stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    writeln!(writer, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"));

    // connection still alive for a valid request
    writeln!(
        writer,
        "{}",
        protocol::request_json(1, "mlp", data.test_mnist.x.row(0))
    )
    .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = protocol::Response::parse(line.trim()).unwrap();
    assert!(resp.result.is_ok());
}

#[test]
fn concurrent_clients_all_served() {
    let Some((svc, data, _)) = trained_service() else { return };
    let svc = Arc::new(svc);
    let server = Server::bind(svc.clone()).unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.run());

    let mut handles = Vec::new();
    for c in 0..4 {
        let x = data.test_mnist.x.row(c).to_vec();
        handles.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            stream.set_nodelay(true).unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut reader = BufReader::new(stream);
            let mut ok = 0;
            for i in 0..8u64 {
                writeln!(writer, "{}", protocol::request_json(i, "mlp", &x)).unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                if protocol::Response::parse(line.trim()).unwrap().result.is_ok() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 32);
    // dynamic batching should have coalesced concurrent load
    assert!(svc.metrics.mean_batch_size() >= 1.0);
}
