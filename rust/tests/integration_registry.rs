//! Registry end-to-end: hot swap under pipelined in-flight load, the
//! global plan-memory budget, and the versioned admin wire protocol.
//!
//! The hot-swap contract under test: a `swap` while requests are in
//! flight completes with **zero dropped or error responses**; every
//! request submitted before the swap is verifiably served by the
//! pre-swap version (the `version` tag in its response), every request
//! submitted after it by the new version; and once the old version's
//! last in-flight holder drains, its executor — compiled-plan cache
//! included — is freed (observed through `Registry::live_versions`).
//!
//! Uses synthetic posteriors written to temp NPZ archives so the suite
//! runs without trained artifacts.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::{self, channel};
use std::sync::Arc;
use std::time::{Duration, Instant};

use pfp::coordinator::{protocol, ProtoVersion, Server, ServerConfig, Service};
use pfp::model::{Arch, PosteriorWeights, SchedulesBuilder};
use pfp::registry::Registry;

fn write_weights(tag: &str, seed: u64) -> std::path::PathBuf {
    let arch = Arch::mlp();
    let path = std::env::temp_dir().join(format!(
        "pfp_intreg_{}_{tag}.npz",
        std::process::id()
    ));
    PosteriorWeights::synthetic(&arch, seed).save_npz(&path).unwrap();
    path
}

fn registry_service(budget: Option<usize>, max_batch: usize) -> Service {
    let mut cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    };
    cfg.batcher.max_batch = max_batch;
    cfg.batcher.max_wait = Duration::from_millis(1);
    let mut svc = Service::new(cfg);
    let registry = Arc::new(Registry::new(budget, true, SchedulesBuilder::tuned(1)));
    svc.attach_registry(registry, 1.0);
    svc
}

struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let writer = stream.try_clone().unwrap();
        Self { writer, reader: BufReader::new(stream) }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").unwrap();
    }

    fn recv(&mut self) -> String {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        line.trim().to_string()
    }
}

fn join_within(h: std::thread::JoinHandle<pfp::Result<()>>, timeout: Duration) {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let r = h.join();
        let _ = tx.send(r.is_ok());
    });
    rx.recv_timeout(timeout)
        .expect("Server::run did not terminate after shutdown");
}

#[test]
fn swap_under_pipelined_load_zero_errors_and_version_split() {
    let svc = registry_service(None, 4);
    let p1 = write_weights("swap_v1", 11);
    let p2 = write_weights("swap_v2", 12);
    svc.admin_load("mlp", &p1.to_string_lossy(), None, None).unwrap();

    // first wave: pipelined in-flight load pinned to v1 at submit time
    let (tx, rx) = channel();
    for i in 0..40u64 {
        svc.submit_with_proto(
            protocol::Request {
                id: i,
                model: "mlp".into(),
                input: vec![0.5; 784],
            },
            tx.clone(),
            ProtoVersion::V1,
        )
        .expect("submit");
    }

    // swap while the first wave is still draining through the batcher
    let ack = svc.admin_swap("mlp", &p2.to_string_lossy(), None, None).unwrap();
    assert_eq!(ack.num_field("version").unwrap(), 2.0);

    // second wave lands on v2
    for i in 40..80u64 {
        svc.submit_with_proto(
            protocol::Request {
                id: i,
                model: "mlp".into(),
                input: vec![0.5; 784],
            },
            tx.clone(),
            ProtoVersion::V1,
        )
        .expect("submit");
    }
    drop(tx);

    let mut count = 0usize;
    for resp in rx.iter() {
        assert!(
            resp.result.is_ok(),
            "swap must drop zero requests, id {} errored: {:?}",
            resp.id,
            resp.result
        );
        assert_eq!(resp.proto, ProtoVersion::V1);
        let expect = if resp.id < 40 { 1 } else { 2 };
        assert_eq!(
            resp.model_version, expect,
            "id {} served by wrong version",
            resp.id
        );
        count += 1;
    }
    assert_eq!(count, 80, "every request must be answered exactly once");

    // once the last v1 holder drains, the old executor (and its whole
    // compiled-plan cache) frees at refcount zero
    let registry = svc.registry().unwrap();
    let t = Instant::now();
    while registry.live_versions("mlp") != vec![2] {
        assert!(
            t.elapsed() < Duration::from_secs(5),
            "v1 not drained: live versions {:?}",
            registry.live_versions("mlp")
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
}

#[test]
fn memory_budget_evicts_lru_plans_across_models() {
    // 1-byte budget: after every batch the worker holds the fleet to the
    // budget, so no compiled plan may stay resident
    let svc = registry_service(Some(1), 4);
    let pa = write_weights("budget_a", 13);
    let pb = write_weights("budget_b", 14);
    svc.admin_load("a", &pa.to_string_lossy(), Some("mlp"), None).unwrap();
    svc.admin_load("b", &pb.to_string_lossy(), Some("mlp"), None).unwrap();

    for (i, name) in ["a", "b", "a"].iter().enumerate() {
        let resp = svc.infer_blocking(protocol::Request {
            id: i as u64,
            model: name.to_string(),
            input: vec![0.25; 784],
        });
        assert!(resp.result.is_ok(), "budget pressure must not fail serving");
    }

    let registry = svc.registry().unwrap();
    assert!(
        registry.budget_evictions() >= 2,
        "each model's plan must have been evicted at least once, got {}",
        registry.budget_evictions()
    );
    assert_eq!(registry.total_plan_bytes(), 0, "nothing fits a 1-byte budget");
    // budget evictions surface in the global metrics counter too
    assert!(
        svc.metrics
            .plan_cache_evictions
            .load(std::sync::atomic::Ordering::Relaxed)
            >= 2
    );
    std::fs::remove_file(&pa).ok();
    std::fs::remove_file(&pb).ok();
}

#[test]
fn versioned_admin_protocol_over_tcp() {
    let svc = Arc::new(registry_service(None, 8));
    let p1 = write_weights("wire_v1", 15);
    let p2 = write_weights("wire_v2", 16);
    let server = Server::bind(svc.clone()).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());
    let mut c = Client::connect(addr);

    // legacy v0 line: accepted, but the first ack carries the one-time
    // deprecation warning — and only the first
    c.send(r#"{"cmd":"ping"}"#);
    let ack = c.recv();
    assert!(ack.contains("pong"), "bad ping ack: {ack}");
    assert!(ack.contains("deprecated"), "first v0 ack must warn: {ack}");
    c.send(r#"{"cmd":"ping"}"#);
    let ack = c.recv();
    assert!(ack.contains("pong") && !ack.contains("deprecated"), "{ack}");

    // unknown protocol versions are rejected outright
    c.send(r#"{"v":9,"cmd":"ping"}"#);
    let ack = c.recv();
    assert!(ack.contains("unknown protocol version"), "{ack}");

    // v1 admin: load -> models -> infer -> swap -> infer -> unload
    c.send(&format!(
        r#"{{"v":1,"cmd":"load","model":"mlp","path":"{}"}}"#,
        p1.display()
    ));
    let ack = c.recv();
    assert!(ack.contains("\"loaded\":true"), "{ack}");
    assert!(ack.contains("\"v\":1"), "v1 command gets a v1 envelope: {ack}");
    assert!(ack.contains("\"version\":1"), "{ack}");

    c.send(r#"{"v":1,"cmd":"models"}"#);
    let listing = c.recv();
    assert!(listing.contains("\"models\""), "{listing}");
    assert!(listing.contains("\"checksum\""), "{listing}");

    c.send(&protocol::request_json_v1(7, "mlp", &[0.5; 784]));
    let resp = protocol::Response::parse(&c.recv()).unwrap();
    assert!(resp.result.is_ok());
    assert_eq!(resp.proto, ProtoVersion::V1);
    assert_eq!(resp.model_version, 1, "infer response tags the serving version");

    c.send(&format!(
        r#"{{"v":1,"cmd":"swap","model":"mlp","path":"{}"}}"#,
        p2.display()
    ));
    let ack = c.recv();
    assert!(ack.contains("\"swapped\":true"), "{ack}");
    assert!(ack.contains("\"version\":2"), "{ack}");

    c.send(&protocol::request_json_v1(8, "mlp", &[0.5; 784]));
    let resp = protocol::Response::parse(&c.recv()).unwrap();
    assert_eq!(resp.model_version, 2, "post-swap requests serve on v2");

    c.send(r#"{"v":1,"cmd":"unload","model":"mlp"}"#);
    let ack = c.recv();
    assert!(ack.contains("\"unloaded\":true"), "{ack}");
    c.send(&protocol::request_json_v1(9, "mlp", &[0.5; 784]));
    let resp = protocol::Response::parse(&c.recv()).unwrap();
    assert!(resp.result.is_err(), "unloaded model must reject");

    c.send(r#"{"v":1,"cmd":"shutdown"}"#);
    assert!(c.recv().contains("shutting_down"));
    join_within(h, Duration::from_secs(10));
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
}
