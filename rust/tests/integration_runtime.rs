//! PJRT runtime integration: every AOT artifact (including the Pallas
//! variants) loads, compiles and reproduces the JAX golden outputs through
//! the `xla` crate's CPU client — the L1→L2→L3 composition proof.

use pfp::model::npz::Npz;
use pfp::model::{Arch, PosteriorWeights};
use pfp::runtime::Engine;

fn engine() -> Option<(Engine, std::path::PathBuf)> {
    let dir = pfp::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some((Engine::new(&dir).unwrap(), dir))
}

fn check_artifact(name: &str, atol: f32) {
    let Some((engine, dir)) = engine() else { return };
    let goldens = Npz::open(&dir.join("goldens.npz")).unwrap();
    let entry = engine.manifest.entry(name).expect("artifact in manifest");
    let arch = Arch::by_name(&entry.arch).unwrap();
    let calib = entry.calibration_factor.unwrap_or(1.0);
    let weights = PosteriorWeights::load(&dir, &arch, calib).unwrap();

    let model = engine.load(name, &weights).unwrap();
    let x = goldens.tensor(&format!("{name}_x")).unwrap();
    let outs = model.execute(&x).unwrap();

    for (i, out_name) in entry.outputs.iter().enumerate() {
        let want = goldens
            .tensor(&format!("{name}_{out_name}"))
            .unwrap()
            .flatten_2d();
        assert!(
            outs[i].allclose(&want, atol, 1e-4),
            "{name}/{out_name}: PJRT output deviates from JAX golden (max {:.2e})",
            outs[i].max_abs_diff(&want)
        );
    }
}

#[test]
fn pfp_mlp_artifacts_execute() {
    for b in [1usize, 10, 100] {
        check_artifact(&format!("model_mlp_pfp_b{b}"), 1e-4);
    }
}

#[test]
fn pfp_lenet_artifacts_execute() {
    for b in [1usize, 10] {
        check_artifact(&format!("model_lenet_pfp_b{b}"), 1e-4);
    }
}

#[test]
fn det_artifacts_execute() {
    check_artifact("model_mlp_det_b10", 1e-4);
    check_artifact("model_lenet_det_b10", 1e-4);
}

#[test]
fn pallas_artifacts_execute() {
    // interpret-mode Pallas lowered into the same HLO pipeline: the
    // L1 kernel path composes end-to-end through PJRT.
    check_artifact("model_mlp_pfp_pallas_b1", 1e-4);
    check_artifact("model_mlp_pfp_pallas_b10", 1e-4);
    check_artifact("model_lenet_pfp_pallas_b1", 1e-4);
}

#[test]
fn pallas_and_jnp_artifacts_agree() {
    // the two lowerings of the same model must agree on the same input
    let Some((engine, dir)) = engine() else { return };
    let goldens = Npz::open(&dir.join("goldens.npz")).unwrap();
    let arch = Arch::by_name("mlp").unwrap();
    let calib = engine.manifest.calibration_factor("mlp");
    let weights = PosteriorWeights::load(&dir, &arch, calib).unwrap();
    let a = engine.load("model_mlp_pfp_b10", &weights).unwrap();
    let b = engine.load("model_mlp_pfp_pallas_b10", &weights).unwrap();
    let x = goldens.tensor("model_mlp_pfp_b10_x").unwrap();
    let oa = a.execute(&x).unwrap();
    let ob = b.execute(&x).unwrap();
    assert!(oa[0].allclose(&ob[0], 3e-4, 3e-4), "pallas/jnp mu mismatch");
    assert!(oa[1].allclose(&ob[1], 1e-3, 1e-3), "pallas/jnp var mismatch");
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some((engine, dir)) = engine() else { return };
    let arch = Arch::by_name("mlp").unwrap();
    let weights = PosteriorWeights::load(&dir, &arch, 1.0).unwrap();
    let a = engine.load("model_mlp_pfp_b1", &weights).unwrap();
    let b = engine.load("model_mlp_pfp_b1", &weights).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

#[test]
fn unknown_artifact_errors() {
    let Some((engine, dir)) = engine() else { return };
    let arch = Arch::by_name("mlp").unwrap();
    let weights = PosteriorWeights::load(&dir, &arch, 1.0).unwrap();
    assert!(engine.load("model_nope_pfp_b1", &weights).is_err());
}
