//! `pfp::artifacts_dir()` resolution order: `$PFP_ARTIFACTS` env var,
//! then `artifacts/` relative to the current directory, then the crate
//! manifest dir.
//!
//! Env-var and cwd state are process-global, so every scenario runs
//! sequentially inside ONE test function (this file is its own test
//! binary, and cargo runs test binaries one at a time).

use std::path::PathBuf;

#[test]
fn artifacts_dir_resolution_order() {
    // 1. explicit PFP_ARTIFACTS wins over everything, even if the path
    //    does not exist
    std::env::set_var("PFP_ARTIFACTS", "/tmp/pfp-env-override");
    assert_eq!(pfp::artifacts_dir(), PathBuf::from("/tmp/pfp-env-override"));
    std::env::remove_var("PFP_ARTIFACTS");

    // 2. without the env var, an `artifacts/` dir in cwd resolves to the
    //    relative path
    let sandbox =
        std::env::temp_dir().join(format!("pfp-artifacts-order-{}", std::process::id()));
    std::fs::create_dir_all(sandbox.join("artifacts")).unwrap();
    let orig_cwd = std::env::current_dir().unwrap();
    std::env::set_current_dir(&sandbox).unwrap();
    assert_eq!(pfp::artifacts_dir(), PathBuf::from(pfp::ARTIFACTS_DIR));

    // 3. with neither, fall back to <crate manifest dir>/artifacts
    std::fs::remove_dir(sandbox.join("artifacts")).unwrap();
    let d = pfp::artifacts_dir();
    assert!(d.is_absolute(), "manifest-dir fallback must be absolute: {d:?}");
    assert!(d.ends_with(pfp::ARTIFACTS_DIR), "unexpected fallback: {d:?}");

    // and the env var still overrides the fallback
    std::env::set_var("PFP_ARTIFACTS", "rel/override");
    assert_eq!(pfp::artifacts_dir(), PathBuf::from("rel/override"));
    std::env::remove_var("PFP_ARTIFACTS");

    std::env::set_current_dir(orig_cwd).unwrap();
    let _ = std::fs::remove_dir_all(&sandbox);
}
