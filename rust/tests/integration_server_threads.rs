//! The server's connection pool must grow lazily: an idle server with a
//! large `max_connections` may not own `2 * max_connections` OS threads
//! (128 with defaults) — the ROADMAP's embedded-deployment item.
//!
//! Kept in its own file so sibling tests' thread usage cannot inflate the
//! process-wide thread count this test asserts on.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use pfp::coordinator::{NativePfpBackend, Server, ServerConfig, Service};
use pfp::model::{Arch, PosteriorWeights, Schedules};
use pfp::ops::Schedule;

/// OS threads in this process (Linux); None elsewhere.
fn process_threads() -> Option<usize> {
    if cfg!(target_os = "linux") {
        std::fs::read_dir("/proc/self/task").ok().map(|d| d.count())
    } else {
        None
    }
}

#[test]
fn idle_server_owns_no_connection_threads() {
    let mut cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_connections: 64, // eager sizing would spawn 128 threads here
        // a small dedicated operator pool: keep the (nproc-sized) global
        // pool out of this binary so the thread count stays meaningful
        pool_threads: 2,
        ..Default::default()
    };
    cfg.batcher.max_batch = 4;
    let mut svc = Service::new(cfg);
    let arch = Arch::mlp();
    let weights = PosteriorWeights::synthetic(&arch, 1);
    // struct literal, NOT Schedules::tuned(): the constructor would
    // initialize the nproc-sized process-global pool as its default
    // handle and skew the thread count being asserted
    let schedules = Schedules {
        dense: Schedule::tuned(1),
        conv: Schedule::tuned(1),
        per_layer: Vec::new(),
        vectorized_pool: true,
        relu_threads: 1,
        maxpool_threads: 1,
        plan_threads: 0,
        isa_override: None,
        fuse: pfp::model::FusePolicy::Auto,
        pool: svc.pool().clone(),
        records: None,
    };
    svc.register("mlp", 784, Box::new(NativePfpBackend::new(arch, weights, schedules)));
    let svc = Arc::new(svc);
    let server = Server::bind(svc.clone()).unwrap();
    let addr = server.addr;
    let run = std::thread::spawn(move || server.run());

    // idle: listener + lane worker + 2 operator-pool workers + harness
    // threads — nothing close to the 128 the eager pool would spawn
    std::thread::sleep(std::time::Duration::from_millis(100));
    if let Some(n) = process_threads() {
        assert!(
            n < 32,
            "idle server owns {n} threads — connection pool is not lazy"
        );
    }

    // one live connection grows the pool by exactly its two jobs and the
    // server still serves
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut wire = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(wire, r#"{{"cmd":"ping"}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("pong"), "bad ping reply: {line}");
    if let Some(n) = process_threads() {
        assert!(
            n < 36,
            "one connection grew the pool to {n} threads"
        );
    }

    // clean shutdown
    writeln!(wire, r#"{{"cmd":"shutdown"}}"#).unwrap();
    let _ = reader.read_line(&mut String::new());
    run.join().unwrap().unwrap();
}
