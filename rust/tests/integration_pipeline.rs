//! Pipelined serving end-to-end: a single TCP connection keeping
//! `pipeline_depth` requests in flight must let the dynamic batcher
//! coalesce them into one probabilistic forward pass (the paper's Fig. 7
//! batching advantage, reachable from one socket), responses must come
//! back tagged by id in completion order, depth overruns must get
//! explicit per-request error responses, and a shutdown command must
//! terminate `Server::run` promptly.
//!
//! Uses a synthetic stub backend so the suite runs without trained
//! artifacts.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use pfp::coordinator::{protocol, Backend, BatcherConfig, Server, ServerConfig, Service};
use pfp::tensor::Tensor;

/// Stub backend: fixed moments, optional per-batch delay.
struct StubBackend {
    delay: Duration,
}

impl Backend for StubBackend {
    fn infer(&mut self, x: &Tensor) -> pfp::Result<(Tensor, Tensor)> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let b = x.dim(0);
        Ok((
            Tensor::full(vec![b, 4], 0.5),
            Tensor::full(vec![b, 4], 1e-3),
        ))
    }

    fn name(&self) -> String {
        "stub".into()
    }
}

fn service(max_batch: usize, max_wait_ms: u64, depth: usize, delay_ms: u64) -> Arc<Service> {
    let mut cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        pipeline_depth: depth,
        ..Default::default()
    };
    cfg.batcher = BatcherConfig {
        max_batch,
        max_wait: Duration::from_millis(max_wait_ms),
        capacity: 1024,
    };
    let mut svc = Service::new(cfg);
    svc.register(
        "stub",
        4,
        Box::new(StubBackend { delay: Duration::from_millis(delay_ms) }),
    );
    Arc::new(svc)
}

struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let writer = stream.try_clone().unwrap();
        Self { writer, reader: BufReader::new(stream) }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").unwrap();
    }

    fn recv(&mut self) -> String {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        line.trim().to_string()
    }
}

/// Join `run()`'s thread with a timeout so a hung accept loop fails the
/// test instead of wedging the whole suite.
fn join_within(h: std::thread::JoinHandle<pfp::Result<()>>, timeout: Duration) {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let r = h.join();
        let _ = tx.send(r.is_ok());
    });
    rx.recv_timeout(timeout)
        .expect("Server::run did not terminate after shutdown");
}

#[test]
fn pipelined_burst_coalesces_and_returns_out_of_order_tags() {
    let svc = service(8, 500, 8, 0);
    let server = Server::bind(svc.clone()).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    let mut c = Client::connect(addr);
    c.send(r#"{"cmd":"hello","pipeline":true}"#);
    let ack = c.recv();
    assert!(ack.contains("\"hello\":true"), "bad hello ack: {ack}");
    assert!(ack.contains("\"pipeline\":true"), "bad hello ack: {ack}");

    // a full max_batch burst in flight before reading a single response
    for i in 0..8u64 {
        c.send(&protocol::request_json(i, "stub", &[0.25; 4]));
    }
    let mut ids = HashSet::new();
    for _ in 0..8 {
        let resp = protocol::Response::parse(&c.recv()).unwrap();
        assert!(resp.result.is_ok(), "request {} failed", resp.id);
        ids.insert(resp.id);
    }
    assert_eq!(ids.len(), 8, "each id answered exactly once");

    // the whole burst must have been one backend call...
    assert_eq!(
        svc.metrics.batches.load(Ordering::Relaxed),
        1,
        "full burst must coalesce into a single batch"
    );
    // ...so the acceptance metric holds: mean batch size > 1 from ONE
    // connection (the blocking front end could never achieve this)
    assert!(svc.metrics.mean_batch_size() > 1.0);
    assert_eq!(svc.metrics.in_flight.load(Ordering::Relaxed), 0);

    c.send(r#"{"cmd":"shutdown"}"#);
    assert!(c.recv().contains("shutting_down"));
    drop(c);
    join_within(h, Duration::from_secs(10));
}

#[test]
fn partial_batch_still_flushes_at_deadline() {
    let svc = service(8, 40, 8, 0);
    let server = Server::bind(svc.clone()).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    let mut c = Client::connect(addr);
    c.send(r#"{"cmd":"hello","pipeline":true}"#);
    assert!(c.recv().contains("\"hello\":true"));
    let t0 = Instant::now();
    for i in 0..3u64 {
        c.send(&protocol::request_json(i, "stub", &[0.5; 4]));
    }
    for _ in 0..3 {
        let resp = protocol::Response::parse(&c.recv()).unwrap();
        assert!(resp.result.is_ok());
    }
    let elapsed = t0.elapsed();
    // 3 < max_batch, so the batch can only flush via the max_wait
    // deadline — and must not wait (much) longer than that
    assert!(elapsed >= Duration::from_millis(20), "flushed too early: {elapsed:?}");
    assert!(elapsed < Duration::from_secs(5), "deadline flush hung: {elapsed:?}");
    assert_eq!(
        svc.metrics.batches.load(Ordering::Relaxed),
        1,
        "partial burst must still be one coalesced batch"
    );

    c.send(r#"{"cmd":"shutdown"}"#);
    assert!(c.recv().contains("shutting_down"));
    drop(c);
    join_within(h, Duration::from_secs(10));
}

#[test]
fn shutdown_terminates_run_within_timeout() {
    // regression: the shutdown wake-up poke must dial the *listener*
    // address; dialing the accepted socket's own address left run() hung
    // in accept
    let svc = service(4, 5, 0, 0);
    let server = Server::bind(svc).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    let mut c = Client::connect(addr);
    c.send(r#"{"cmd":"shutdown"}"#);
    assert!(c.recv().contains("shutting_down"));
    drop(c);
    join_within(h, Duration::from_secs(10));
}

#[test]
fn depth_overrun_gets_explicit_per_request_error() {
    // depth 2, slow backend: requests 3.. of an eager burst must be
    // rejected immediately with id-tagged errors while the first two are
    // still inside the backend
    let svc = service(1, 1, 2, 500);
    let server = Server::bind(svc.clone()).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    let mut c = Client::connect(addr);
    c.send(r#"{"cmd":"hello","pipeline":true}"#);
    assert!(c.recv().contains("\"pipeline_depth\":2"));
    for i in 0..5u64 {
        c.send(&protocol::request_json(i, "stub", &[0.1; 4]));
    }
    let mut errors = 0;
    let mut oks = 0;
    let mut ids = HashSet::new();
    let first = protocol::Response::parse(&c.recv()).unwrap();
    assert!(
        first.result.is_err(),
        "depth rejection must arrive before the slow backend answers"
    );
    ids.insert(first.id);
    errors += 1;
    for _ in 0..4 {
        let resp = protocol::Response::parse(&c.recv()).unwrap();
        ids.insert(resp.id);
        match resp.result {
            Ok(_) => oks += 1,
            Err(e) => {
                assert!(e.contains("pipeline depth"), "unexpected error: {e}");
                errors += 1;
            }
        }
    }
    assert_eq!(ids.len(), 5, "every request answered exactly once");
    assert_eq!(errors + oks, 5);
    assert!(oks >= 2, "admitted requests must still succeed (got {oks})");
    assert!(errors >= 1);
    assert_eq!(
        svc.metrics.depth_rejected.load(Ordering::Relaxed),
        errors as u64,
        "depth rejections must be counted"
    );

    c.send(r#"{"cmd":"shutdown"}"#);
    assert!(c.recv().contains("shutting_down"));
    drop(c);
    join_within(h, Duration::from_secs(10));
}

#[test]
fn legacy_synchronous_client_still_works() {
    // an old client: no hello handshake, strict request -> response lockstep
    let svc = service(4, 5, 0, 0);
    let server = Server::bind(svc).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    let mut c = Client::connect(addr);
    for i in 0..3u64 {
        c.send(&protocol::request_json(i, "stub", &[0.3; 4]));
        let resp = protocol::Response::parse(&c.recv()).unwrap();
        assert_eq!(resp.id, i, "lockstep clients see in-order responses");
        assert!(resp.result.is_ok());
    }

    c.send(r#"{"cmd":"shutdown"}"#);
    assert!(c.recv().contains("shutting_down"));
    drop(c);
    join_within(h, Duration::from_secs(10));
}

#[test]
fn legacy_write_pipelining_client_gets_in_order_responses() {
    // an old client that bursts writes but never sent hello must see the
    // pre-pipelining server's behaviour: in-order replies, no depth
    // errors (the reader applies backpressure instead)
    let svc = service(4, 5, 8, 10);
    let server = Server::bind(svc).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    let mut c = Client::connect(addr);
    for i in 0..4u64 {
        c.send(&protocol::request_json(i, "stub", &[0.4; 4]));
    }
    for i in 0..4u64 {
        let resp = protocol::Response::parse(&c.recv()).unwrap();
        assert_eq!(resp.id, i, "legacy clients see submission-order responses");
        assert!(resp.result.is_ok(), "legacy clients never see depth errors");
    }

    c.send(r#"{"cmd":"shutdown"}"#);
    assert!(c.recv().contains("shutting_down"));
    drop(c);
    join_within(h, Duration::from_secs(10));
}

#[test]
fn accept_limit_rejects_excess_connections() {
    let mut cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_connections: 1,
        ..Default::default()
    };
    cfg.batcher = BatcherConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(5),
        capacity: 64,
    };
    let mut service = Service::new(cfg);
    service.register("stub", 4, Box::new(StubBackend { delay: Duration::ZERO }));
    let svc = Arc::new(service);
    let server = Server::bind(svc.clone()).unwrap();
    let addr = server.addr;
    let h = std::thread::spawn(move || server.run());

    let mut c1 = Client::connect(addr);
    // roundtrip proves c1 is admitted before c2 dials in
    c1.send(r#"{"cmd":"ping"}"#);
    assert!(c1.recv().contains("pong"));

    let mut c2 = Client::connect(addr);
    let rejection = c2.recv();
    assert!(
        rejection.contains("max connections"),
        "second connection must be refused at accept: {rejection}"
    );
    assert_eq!(svc.metrics.conns_rejected.load(Ordering::Relaxed), 1);
    drop(c2);

    // the admitted connection is unaffected
    c1.send(&protocol::request_json(7, "stub", &[0.2; 4]));
    let resp = protocol::Response::parse(&c1.recv()).unwrap();
    assert_eq!(resp.id, 7);
    assert!(resp.result.is_ok());

    c1.send(r#"{"cmd":"shutdown"}"#);
    assert!(c1.recv().contains("shutting_down"));
    drop(c1);
    join_within(h, Duration::from_secs(10));
}
