//! End-to-end mixed-precision certification: f16/bf16 moment storage may
//! buy bandwidth, but it must not buy it with the network's calibration
//! or its OOD detection. This is the tier-2 uncertainty budget on top of
//! the bitwise kernel contracts in `integration_simd_parity.rs`.
//!
//! For every (mean precision, variance precision) combination the knobs
//! expose, the harness runs the full serving pipeline — packed compiled
//! plan, Gaussian logit sampling (fixed seed, so the Monte-Carlo noise
//! cancels between combinations), softmax moments — and bounds the drift
//! against the all-f32 reference:
//!
//! * |ECE_packed - ECE_f32| <= 0.05 on the in-domain split,
//! * |AUROC_packed - AUROC_f32| <= 0.05 for MI-based OOD separation,
//! * in-domain accuracy drops by no more than 2 percentage points.
//!
//! Combinations are swept finest-to-coarsest and every violation is
//! reported with the **first breaking combination named** — so when a
//! future kernel change degrades e.g. bf16 variance storage, the failure
//! says exactly which knob setting broke, not just "a test failed".
//!
//! The always-run path certifies on the synthetic Dirty-MNIST generator
//! and synthetic posteriors (self-contained, no artifacts); a second,
//! artifacts-gated path re-certifies on the trained posterior and real
//! exported splits with the same budgets. The f32 override route is also
//! pinned bit-identical to the plain f32 path here: `--precision f32` is
//! a no-op by construction, not by luck.

use pfp::data::DirtyMnist;
use pfp::model::{Arch, PfpExecutor, PosteriorWeights, Schedules};
use pfp::runtime::Manifest;
use pfp::tensor::Tensor;
use pfp::uncertainty;
use pfp::util::half::Precision;

const ECE_BUDGET: f64 = 0.05;
const AUROC_BUDGET: f64 = 0.05;
const ACC_BUDGET: f64 = 0.02;
const SAMPLES: usize = 20;
const SAMPLE_SEED: u64 = 42;
const ECE_BINS: usize = 10;

/// Finest-to-coarsest sweep of every non-reference (mean, var) storage
/// combination the Schedule knobs expose.
const GRID: [(Precision, Precision); 8] = [
    (Precision::F32, Precision::F16),
    (Precision::F16, Precision::F32),
    (Precision::F16, Precision::F16),
    (Precision::F32, Precision::Bf16),
    (Precision::Bf16, Precision::F32),
    (Precision::F16, Precision::Bf16),
    (Precision::Bf16, Precision::F16),
    (Precision::Bf16, Precision::Bf16),
];

struct Metrics {
    acc: f64,
    ece: f64,
    auroc: f64,
}

/// Full pipeline at one precision setting: packed plan forward on every
/// split, fixed-seed logit sampling, ECE/accuracy in-domain, MI-AUROC
/// for dirty-vs-OOD.
fn eval_at(
    arch: &Arch,
    weights: &PosteriorWeights,
    data: &DirtyMnist,
    mean_p: Precision,
    var_p: Precision,
) -> Metrics {
    let sched = Schedules::tuned(1)
        .with_precision_override(Some(mean_p))
        .with_var_precision(Some(var_p));
    let mut exec = PfpExecutor::new(arch.clone(), weights.clone(), sched);
    let k = arch.num_classes();
    let mut uncert = |x: &Tensor| {
        let (mu, var) = exec.forward(x);
        uncertainty::pfp_uncertainty(&mu, &var, SAMPLES, SAMPLE_SEED)
    };
    let u_in = uncert(&data.test_mnist.x);
    let u_amb = uncert(&data.test_ambiguous.x);
    let u_ood = uncert(&data.test_ood.x);
    let in_mi: Vec<f64> = u_in.mi.iter().chain(&u_amb.mi).cloned().collect();
    Metrics {
        acc: uncertainty::accuracy(&u_in.mean_p, k, &data.test_mnist.y),
        ece: uncertainty::ece(&u_in.mean_p, k, &data.test_mnist.y, ECE_BINS),
        auroc: uncertainty::auroc(&u_ood.mi, &in_mi),
    }
}

/// Sweep the grid against the f32 reference; panic naming the first
/// combination that exceeds any budget.
fn certify(tag: &str, arch: &Arch, weights: &PosteriorWeights, data: &DirtyMnist) {
    let reference = eval_at(arch, weights, data, Precision::F32, Precision::F32);
    eprintln!(
        "[{tag}] f32 reference: acc={:.3} ece={:.3} auroc={:.3}",
        reference.acc, reference.ece, reference.auroc
    );
    let mut first_break: Option<String> = None;
    for (mean_p, var_p) in GRID {
        let m = eval_at(arch, weights, data, mean_p, var_p);
        let d_ece = (m.ece - reference.ece).abs();
        let d_auroc = (m.auroc - reference.auroc).abs();
        let d_acc = reference.acc - m.acc; // only degradation counts
        eprintln!(
            "[{tag}] mean={mean_p} var={var_p}: acc={:.3} (Δ{:+.3}) \
             ece={:.3} (Δ{:.3}) auroc={:.3} (Δ{:.3})",
            m.acc, -d_acc, m.ece, d_ece, m.auroc, d_auroc
        );
        if first_break.is_none()
            && (d_ece > ECE_BUDGET || d_auroc > AUROC_BUDGET || d_acc > ACC_BUDGET)
        {
            first_break = Some(format!(
                "mean={mean_p} var={var_p} (Δece={d_ece:.4} Δauroc={d_auroc:.4} \
                 Δacc={d_acc:.4})"
            ));
        }
    }
    if let Some(combo) = first_break {
        panic!("[{tag}] first combination over budget: {combo}");
    }
}

#[test]
fn synthetic_certification_mlp_full_grid() {
    let arch = Arch::mlp();
    let weights = PosteriorWeights::synthetic(&arch, 7);
    let data = DirtyMnist::generate(2025, 96);
    certify("synthetic mlp", &arch, &weights, &data);
}

#[test]
fn synthetic_certification_lenet_smoke() {
    // lenet exercises the packed conv + pool path; one coarse combination
    // keeps the debug-build runtime reasonable while the full grid runs
    // on the (cheap) mlp above
    let arch = Arch::lenet();
    let weights = PosteriorWeights::synthetic(&arch, 7);
    let data = DirtyMnist::generate(2025, 24);
    let reference = eval_at(&arch, &weights, &data, Precision::F32, Precision::F32);
    let m = eval_at(&arch, &weights, &data, Precision::F16, Precision::F16);
    assert!(
        (m.ece - reference.ece).abs() <= ECE_BUDGET,
        "lenet f16 ECE drift {:.4} over budget",
        (m.ece - reference.ece).abs()
    );
    assert!(
        (m.auroc - reference.auroc).abs() <= AUROC_BUDGET,
        "lenet f16 AUROC drift {:.4} over budget",
        (m.auroc - reference.auroc).abs()
    );
}

#[test]
fn f32_override_is_bit_identical_to_plain_f32() {
    // `--precision f32` must be a pure no-op: same plan, same bits
    for arch in [Arch::mlp(), Arch::lenet()] {
        let weights = PosteriorWeights::synthetic(&arch, 11);
        let x = Tensor::new(
            vec![3, arch.input_len()],
            (0..3 * arch.input_len())
                .map(|i| (i % 97) as f32 / 97.0)
                .collect(),
        )
        .unwrap();
        let (mu_a, var_a) =
            PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1)).forward(&x);
        let (mu_b, var_b) = PfpExecutor::new(
            arch.clone(),
            weights.clone(),
            Schedules::tuned(1)
                .with_precision_override(Some(Precision::F32))
                .with_var_precision(Some(Precision::F32)),
        )
        .forward(&x);
        assert_eq!(mu_a.data(), mu_b.data(), "{} mu", arch.name);
        assert_eq!(var_a.data(), var_b.data(), "{} var", arch.name);
    }
}

#[test]
fn trained_posterior_certification_when_artifacts_present() {
    // golden-path re-certification on the trained posterior and the real
    // exported splits; same budgets as the synthetic path
    let dir = pfp::artifacts_dir();
    if !dir.join("data.npz").exists() || !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let arch = Arch::mlp();
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let calib = manifest.calibration_factor(&arch.name);
    let weights = PosteriorWeights::load(&dir, &arch, calib).unwrap();
    let data = DirtyMnist::load(&dir).unwrap();
    certify("trained mlp", &arch, &weights, &data);
}
