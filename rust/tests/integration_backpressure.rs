//! Batcher backpressure end-to-end: when a model lane's bounded queue is
//! saturated (worker busy + queue at capacity), a new request must be
//! rejected *immediately* with an error `Response` — never block the
//! submitter until the queue drains.

use std::sync::Arc;
use std::time::{Duration, Instant};

use pfp::coordinator::{protocol, Backend, BatcherConfig, ServerConfig, Service};
use pfp::tensor::Tensor;

/// Backend that holds the lane worker busy for a fixed delay per batch.
struct SlowBackend {
    delay: Duration,
}

impl Backend for SlowBackend {
    fn infer(&mut self, x: &Tensor) -> pfp::Result<(Tensor, Tensor)> {
        std::thread::sleep(self.delay);
        let b = x.dim(0);
        Ok((
            Tensor::full(vec![b, 4], 0.5),
            Tensor::full(vec![b, 4], 1e-3),
        ))
    }

    fn name(&self) -> String {
        "slow".into()
    }
}

fn req(id: u64) -> protocol::Request {
    protocol::Request { id, model: "slow".into(), input: vec![0.0; 4] }
}

#[test]
fn full_queue_rejects_immediately_with_error_response() {
    let mut cfg = ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
    cfg.batcher = BatcherConfig {
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        capacity: 2,
    };
    let mut svc = Service::new(cfg);
    svc.register("slow", 4, Box::new(SlowBackend { delay: Duration::from_millis(400) }));
    let svc = Arc::new(svc);

    // request 0 is dequeued by the lane worker (which then sleeps inside
    // infer); requests 1 and 2 fill the bounded queue to capacity
    let mut waiters = Vec::new();
    waiters.push(svc.submit(req(0)).expect("within capacity: accepted"));
    // wait (bounded) until the worker has actually pulled request 0 off
    // the queue — the batch counter increments before infer runs
    let deadline = Instant::now() + Duration::from_secs(5);
    while svc.metrics.batches.load(std::sync::atomic::Ordering::Relaxed) < 1 {
        assert!(Instant::now() < deadline, "worker never picked up request 0");
        std::thread::sleep(Duration::from_millis(5));
    }
    for id in 1..3u64 {
        waiters.push(svc.submit(req(id)).expect("within capacity: accepted"));
    }

    // the queue is now full: the next request must fail fast with an
    // error Response while the worker is still busy (~400ms left)
    let t = Instant::now();
    let resp = svc.infer_blocking(req(99));
    let elapsed = t.elapsed();
    let err = resp.result.expect_err("saturated queue must reject");
    assert!(err.contains("queue full"), "unexpected error: {err}");
    assert!(
        elapsed < Duration::from_millis(200),
        "rejection must not block: took {elapsed:?}"
    );
    assert_eq!(resp.id, 99);
    assert_eq!(
        svc.metrics.rejected.load(std::sync::atomic::Ordering::Relaxed),
        1
    );

    // accepted requests are unaffected: all three eventually succeed
    for rx in waiters {
        let r = rx.recv().expect("worker reply");
        assert!(r.result.is_ok(), "queued request failed: {:?}", r.result.err());
    }
}
