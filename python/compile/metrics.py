"""Uncertainty metrics (paper Section 2.2, Eqs. 1-3) and OOD evaluation.

Mirrored in ``rust/src/uncertainty/``; cross-checked by goldens.

Sample-based pipeline (SVI, and PFP after Eq. 11 logit sampling):
  probs [S, N, K] ->
    total  = Shannon entropy of the mean predictive  (Eq. 1)
    sme    = mean of the per-sample softmax entropies (Eq. 2, aleatoric)
    mi     = total - sme                              (Eq. 3, epistemic)
"""

from __future__ import annotations

import numpy as np

EPS = 1e-12


def softmax(logits: np.ndarray, axis: int = -1) -> np.ndarray:
    z = logits - logits.max(axis=axis, keepdims=True)
    e = np.exp(z)
    return e / e.sum(axis=axis, keepdims=True)


def entropy(p: np.ndarray, axis: int = -1) -> np.ndarray:
    return -(p * np.log(p + EPS)).sum(axis=axis)


def uncertainty_from_probs(probs: np.ndarray) -> dict[str, np.ndarray]:
    """probs: [S, N, K] per-sample class probabilities."""
    mean_p = probs.mean(axis=0)                     # [N, K]
    total = entropy(mean_p)                         # Eq. 1
    sme = entropy(probs).mean(axis=0)               # Eq. 2
    mi = np.maximum(total - sme, 0.0)               # Eq. 3
    return {"total": total, "sme": sme, "mi": mi, "mean_p": mean_p}


def sample_logits_gaussian(mu: np.ndarray, var: np.ndarray, n_samples: int,
                           seed: int = 0) -> np.ndarray:
    """Eq. 11: draw logit samples from N(mu_PFP, sigma^2_PFP).

    mu, var: [N, K] -> [S, N, K].
    """
    rng = np.random.default_rng(seed)
    std = np.sqrt(np.maximum(var, 0.0))
    return mu[None] + std[None] * rng.standard_normal(
        (n_samples,) + mu.shape
    ).astype(np.float32)


def accuracy(mean_p: np.ndarray, labels: np.ndarray) -> float:
    return float((mean_p.argmax(axis=-1) == labels).mean())


def auroc(scores_pos: np.ndarray, scores_neg: np.ndarray) -> float:
    """AUROC for separating positives (OOD, should score high) from
    negatives (in-domain).  Rank-based (Mann-Whitney U), ties counted 0.5.
    """
    pos = np.asarray(scores_pos, dtype=np.float64)
    neg = np.asarray(scores_neg, dtype=np.float64)
    all_scores = np.concatenate([pos, neg])
    order = np.argsort(all_scores, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    sorted_scores = all_scores[order]
    i = 0
    n = len(all_scores)
    while i < n:
        j = i
        while j + 1 < n and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        ranks[order[i : j + 1]] = 0.5 * (i + j) + 1.0
        i = j + 1
    r_pos = ranks[: len(pos)].sum()
    u = r_pos - len(pos) * (len(pos) + 1) / 2.0
    return float(u / (len(pos) * len(neg)))
