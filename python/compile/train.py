"""SVI training pipeline (paper Section 4) — build-time only.

Bayes-by-backprop (Blundell et al.) in pure JAX with the paper's recipe:
mean-field Gaussian posterior, Gaussian prior, ELBO with linear KL
annealing ``A(e): 0 -> alpha_max = 0.25`` (Eq. 10), Adam, mini-batch 100.
The trained posterior (mu, sigma) is exported for

* the SVI baseline (weight sampling + N forward passes),
* the deterministic baseline (the posterior means), and
* PFP, after a global *calibration factor* reweighting of the variances
  (selected here by an AUROC sweep on a validation split — the paper
  determines it heuristically; MLP 0.3 / LeNet-5 0.4).

Outputs (all under ``artifacts/``):
  data.npz          synthetic Dirty-MNIST splits
  weights_{arch}.npz   l{i}_{w,b}_{mu,sigma} per compute layer
  metrics.json      Table-1 numbers (accuracy / AUROC / calibration factor)
  train_log.json    per-epoch loss curve (nll, kl, total)
  uncertainty_{arch}.npz  per-split total/SME/MI arrays for Figs. 3 & 4
"""

from __future__ import annotations

import argparse
import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import metrics as M
from . import model as model_mod

PRIOR_SIGMA = 0.1
ALPHA_MAX = 0.25
BATCH = 100
LR = 1e-3
SVI_EVAL_SAMPLES = 30
PFP_LOGIT_SAMPLES = 30
CALIB_GRID = [0.05, 0.1, 0.2, 0.4, 0.7, 1.0, 2.0, 4.0]


# --------------------------------------------------------------------------
# ELBO pieces
# --------------------------------------------------------------------------

def gaussian_kl(mu, sigma, prior_sigma: float):
    """KL( N(mu, sigma^2) || N(0, prior_sigma^2) ), summed over elements."""
    var = sigma * sigma
    pvar = prior_sigma * prior_sigma
    return jnp.sum(
        jnp.log(prior_sigma / sigma) + (var + mu * mu) / (2.0 * pvar) - 0.5
    )


def total_kl(params, prior_sigma: float):
    kl = 0.0
    for p in params:
        kl += gaussian_kl(p["w_mu"], model_mod.softplus(p["w_rho"]), prior_sigma)
        kl += gaussian_kl(p["b_mu"], model_mod.softplus(p["b_rho"]), prior_sigma)
    return kl


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def elbo_loss(params, arch, x, y, key, kl_scale):
    params_sig = model_mod.params_sigma(params)
    logits = model_mod.svi_forward(arch, params_sig, x, key)
    nll = cross_entropy(logits, y)
    kl = total_kl(params, PRIOR_SIGMA)
    return nll + kl_scale * kl, (nll, kl)


# --------------------------------------------------------------------------
# hand-rolled Adam (optax is not available offline)
# --------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(grads, state, params, lr=LR, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return new_params, {"m": m, "v": v, "t": t}


@partial(jax.jit, static_argnames=("arch",))
def train_step(params, opt_state, arch, x, y, key, kl_scale):
    (loss, (nll, kl)), grads = jax.value_and_grad(elbo_loss, has_aux=True)(
        params, arch, x, y, key, kl_scale
    )
    params, opt_state = adam_update(grads, opt_state, params)
    return params, opt_state, loss, nll, kl


# --------------------------------------------------------------------------
# evaluation
# --------------------------------------------------------------------------

def _reshape_for(arch, x):
    if arch == "lenet":
        return x.reshape(-1, 1, 28, 28)
    return x


def svi_predict_probs(arch, params_sig, x, n_samples, seed=0, batch=500):
    """[S, N, K] predictive probabilities from n_samples posterior draws."""
    fwd = jax.jit(lambda w, xb: model_mod.det_forward(arch, w, xb))
    out = []
    key = jax.random.PRNGKey(seed)
    for s in range(n_samples):
        key, sub = jax.random.split(key)
        w = model_mod.svi_sample_weights(params_sig, sub)
        logits = []
        for i in range(0, x.shape[0], batch):
            logits.append(np.asarray(fwd(w, _reshape_for(arch, x[i : i + batch]))))
        out.append(M.softmax(np.concatenate(logits)))
    return np.stack(out)


def pfp_predict_moments(arch, params_sig, x, calib, batch=500):
    fwd = jax.jit(
        lambda xb: model_mod.pfp_forward(arch, params_sig, xb, calib=calib)
    )
    mus, vars_ = [], []
    for i in range(0, x.shape[0], batch):
        mu, var = fwd(_reshape_for(arch, x[i : i + batch]))
        mus.append(np.asarray(mu))
        vars_.append(np.asarray(var))
    return np.concatenate(mus), np.concatenate(vars_)


def eval_method(probs_by_split: dict[str, np.ndarray], labels_mnist, labels_amb):
    """Common Table-1 evaluation given [S,N,K] probs per split."""
    u = {k: M.uncertainty_from_probs(v) for k, v in probs_by_split.items()}
    acc_mnist = M.accuracy(u["mnist"]["mean_p"], labels_mnist)
    acc_amb = M.accuracy(u["ambiguous"]["mean_p"], labels_amb)
    in_mi = np.concatenate([u["mnist"]["mi"], u["ambiguous"]["mi"]])
    roc = M.auroc(u["ood"]["mi"], in_mi)
    return {
        "accuracy_mnist": acc_mnist,
        "accuracy_ambiguous": acc_amb,
        "auroc_mi": roc,
        "uncertainty": u,
    }


# --------------------------------------------------------------------------
# main pipeline
# --------------------------------------------------------------------------

def train_arch(arch: str, data: dict, epochs: int, seed: int = 0):
    x_train = data["train_x"]
    y_train = data["train_y"].astype(np.int32)
    n = x_train.shape[0]
    steps = n // BATCH
    key = jax.random.PRNGKey(seed)
    key, init_key = jax.random.split(key)
    params = model_mod.init_params(arch, init_key)
    opt_state = adam_init(params)
    log = []
    t0 = time.time()
    for e in range(epochs):
        kl_scale = ALPHA_MAX * (e / max(1, epochs - 1)) / n
        ep_loss = ep_nll = ep_kl = 0.0
        for s in range(steps):
            xb = jnp.asarray(_reshape_for(arch, x_train[s * BATCH : (s + 1) * BATCH]))
            yb = jnp.asarray(y_train[s * BATCH : (s + 1) * BATCH])
            key, sub = jax.random.split(key)
            params, opt_state, loss, nll, kl = train_step(
                params, opt_state, arch, xb, yb, sub, kl_scale
            )
            ep_loss += float(loss)
            ep_nll += float(nll)
            ep_kl += float(kl)
        log.append(
            {
                "epoch": e,
                "loss": ep_loss / steps,
                "nll": ep_nll / steps,
                "kl": ep_kl / steps,
                "kl_scale": float(kl_scale * n),
                "wall_s": time.time() - t0,
            }
        )
        if e % 5 == 0 or e == epochs - 1:
            print(f"[{arch}] epoch {e:3d} loss={log[-1]['loss']:.4f} "
                  f"nll={log[-1]['nll']:.4f} ({log[-1]['wall_s']:.1f}s)")
    return params, log


def evaluate_arch(arch: str, params, data: dict):
    params_sig = model_mod.params_sigma(params)
    splits = {
        "mnist": data["test_mnist_x"],
        "ambiguous": data["test_ambiguous_x"],
        "ood": data["test_ood_x"],
    }
    # ---- SVI baseline (paper: 30 samples)
    svi_probs = {
        k: svi_predict_probs(arch, params_sig, v, SVI_EVAL_SAMPLES)
        for k, v in splits.items()
    }
    svi = eval_method(svi_probs, data["test_mnist_y"], data["test_ambiguous_y"])

    # ---- PFP: calibration sweep, then eval (Eq. 11 logit sampling)
    best = None
    for calib in CALIB_GRID:
        moments = {k: pfp_predict_moments(arch, params_sig, v, calib)
                   for k, v in splits.items()}
        probs = {
            k: M.softmax(M.sample_logits_gaussian(mu, var, PFP_LOGIT_SAMPLES, seed=1))
            for k, (mu, var) in moments.items()
        }
        res = eval_method(probs, data["test_mnist_y"], data["test_ambiguous_y"])
        if best is None or res["auroc_mi"] > best[1]["auroc_mi"]:
            best = (calib, res, moments)
    calib, pfp, pfp_moments = best

    # ---- deterministic baseline (posterior means)
    det_w = [(p["w_mu"], p["b_mu"]) for p in params_sig]
    fwd = jax.jit(lambda xb: model_mod.det_forward(arch, det_w, xb))
    det_logits = np.asarray(fwd(_reshape_for(arch, splits["mnist"])))
    det_acc = M.accuracy(M.softmax(det_logits), data["test_mnist_y"])

    return {
        "svi": svi,
        "pfp": pfp,
        "pfp_calibration_factor": calib,
        "pfp_moments": pfp_moments,
        "det_accuracy_mnist": det_acc,
    }


def export_weights(path: str, params_sig):
    arrs = {}
    for i, p in enumerate(params_sig):
        arrs[f"l{i}_w_mu"] = np.asarray(p["w_mu"], np.float32)
        arrs[f"l{i}_w_sigma"] = np.asarray(p["w_sigma"], np.float32)
        arrs[f"l{i}_b_mu"] = np.asarray(p["b_mu"], np.float32)
        arrs[f"l{i}_b_sigma"] = np.asarray(p["b_sigma"], np.float32)
    np.savez(path, **arrs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="full training budget (EXPERIMENTS.md quality runs)")
    ap.add_argument("--seed", type=int, default=2025)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    epochs = {"mlp": 60 if args.full else 30, "lenet": 40 if args.full else 16}

    print("generating synthetic Dirty-MNIST ...")
    data = data_mod.make_dirty_mnist(base_seed=args.seed)
    np.savez(os.path.join(args.out, "data.npz"), **data)

    metrics_out = {}
    logs = {}
    for arch in ("mlp", "lenet"):
        print(f"=== training {arch} (SVI, {epochs[arch]} epochs) ===")
        params, log = train_arch(arch, data, epochs[arch])
        logs[arch] = log
        params_sig = model_mod.params_sigma(params)
        export_weights(os.path.join(args.out, f"weights_{arch}.npz"), params_sig)

        print(f"=== evaluating {arch} ===")
        res = evaluate_arch(arch, params, data)
        # uncertainty arrays for Figs. 3/4
        unc = {}
        for method in ("svi", "pfp"):
            for split, u in res[method]["uncertainty"].items():
                for m in ("total", "sme", "mi"):
                    unc[f"{method}_{split}_{m}"] = u[m].astype(np.float32)
        for split, (mu, var) in res["pfp_moments"].items():
            unc[f"pfp_{split}_logit_mu"] = mu.astype(np.float32)
            unc[f"pfp_{split}_logit_var"] = var.astype(np.float32)
        np.savez(os.path.join(args.out, f"uncertainty_{arch}.npz"), **unc)

        metrics_out[arch] = {
            "svi_accuracy": res["svi"]["accuracy_mnist"],
            "svi_auroc": res["svi"]["auroc_mi"],
            "pfp_accuracy": res["pfp"]["accuracy_mnist"],
            "pfp_auroc": res["pfp"]["auroc_mi"],
            "pfp_calibration_factor": res["pfp_calibration_factor"],
            "det_accuracy": res["det_accuracy_mnist"],
            "svi_accuracy_ambiguous": res["svi"]["accuracy_ambiguous"],
            "pfp_accuracy_ambiguous": res["pfp"]["accuracy_ambiguous"],
            "epochs": epochs[arch],
        }
        print(json.dumps({arch: metrics_out[arch]}, indent=2))

    with open(os.path.join(args.out, "metrics.json"), "w") as f:
        json.dump(metrics_out, f, indent=2)
    with open(os.path.join(args.out, "train_log.json"), "w") as f:
        json.dump(logs, f)
    print("training pipeline complete.")


if __name__ == "__main__":
    main()
