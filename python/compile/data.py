"""Synthetic Dirty-MNIST substitute ("synthdigits").

The paper evaluates on Dirty-MNIST = MNIST (in-domain) + Ambiguous-MNIST
(aleatoric, between-class) + Fashion-MNIST (OOD, epistemic).  Those datasets
are not available in this offline environment, so we generate a synthetic
equivalent that preserves exactly the structure the experiments exercise:

* ``indomain``  — 10 well-separated classes: class-seeded sinusoid/Gabor
  prototypes on a 28x28 grid with a centered radial envelope (digit-like,
  smooth), plus per-sample integer shifts and Gaussian pixel noise.
* ``ambiguous`` — convex blends of two class prototypes with blend factor
  lambda in [0.35, 0.65], labelled with the first class: genuinely
  between-class probability mass -> high aleatoric uncertainty.
* ``ood``       — structurally different textures (checkerboards, random
  rectangles, stripes) sharing the input value range but not the class
  manifold -> high epistemic uncertainty.

The generator is driven by a SplitMix64 PRNG and is mirrored draw-for-draw
in Rust (``rust/src/data/synth.rs``); cross-language agreement is asserted
(to float tolerance — libm transcendentals may differ in the last ulp) by
``rust/tests/integration_data.rs`` against goldens exported here.

SplitMix64 lets us vectorise without changing the draw sequence: the k-th
output from state ``s`` is ``mix(s + k*GOLDEN)``, so a numpy batch of n
draws equals n sequential ``next_u64`` calls (the Rust side is the scalar
loop).

All images are float32 in [0, 1], flattened to 784 for the MLP and reshaped
to [N, 1, 28, 28] for LeNet-5.
"""

from __future__ import annotations

import math

import numpy as np

H = W = 28
NUM_CLASSES = 10
IMG = H * W

GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def _mix(z: np.ndarray) -> np.ndarray:
    z = (z ^ (z >> np.uint64(30))) * _MIX1
    z = (z ^ (z >> np.uint64(27))) * _MIX2
    return z ^ (z >> np.uint64(31))


class SplitMix64:
    """SplitMix64 PRNG; mirrored bit-for-bit in rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        self.state = np.uint64(seed & 0xFFFFFFFFFFFFFFFF)

    def next_u64(self) -> int:
        with np.errstate(over="ignore"):
            self.state = self.state + GOLDEN
            return int(_mix(self.state))

    def next_array(self, n: int) -> np.ndarray:
        """n sequential next_u64() draws, vectorised (same sequence)."""
        with np.errstate(over="ignore"):
            ks = np.arange(1, n + 1, dtype=np.uint64) * GOLDEN + self.state
            self.state = self.state + np.uint64(n) * GOLDEN
            return _mix(ks)

    def uniform(self) -> float:
        """float in [0, 1) with 24 bits of mantissa (f32-exact)."""
        return float(np.uint64(self.next_u64()) >> np.uint64(40)) / float(1 << 24)

    def uniform_array(self, n: int) -> np.ndarray:
        return (self.next_array(n) >> np.uint64(40)).astype(np.float64) / float(1 << 24)

    def randint(self, n: int) -> int:
        return int(np.uint64(self.next_u64()) % np.uint64(n))

    def normal(self) -> float:
        u = self.uniform_array(2)
        u1 = max(u[0], 1e-12)
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u[1])

    def normal_array(self, n: int) -> np.ndarray:
        """n Box-Muller (cosine branch) normals; 2n uniform draws,
        interleaved (u1, u2) per normal — identical to n scalar calls."""
        u = self.uniform_array(2 * n)
        u1 = np.maximum(u[0::2], 1e-12)
        u2 = u[1::2]
        return np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)


def derive_seed(base: int, stream: int, index: int) -> int:
    """Per-sample seed so each sample is independent of generation order."""
    mix = SplitMix64((base ^ (stream * 0x9E3779B1) ^ (index * 0x85EBCA77)) & 0xFFFFFFFFFFFFFFFF)
    return mix.next_u64()


# --------------------------------------------------------------------------
# class prototypes
# --------------------------------------------------------------------------

def class_prototype(c: int) -> np.ndarray:
    """Deterministic 28x28 prototype for class ``c`` (no randomness).

    Distinct spatial frequency pair per class, radial envelope so the
    pattern is centered like a digit.
    """
    fx = 1.0 + float(c % 3)
    fy = 1.0 + float(c // 3)
    phase = 0.7 * float(c)
    i = np.arange(H, dtype=np.float64)[:, None] / (H - 1)
    j = np.arange(W, dtype=np.float64)[None, :] / (W - 1)
    env = np.exp(-((i - 0.5) ** 2 + (j - 0.5) ** 2) * 4.0)
    s = np.sin(2.0 * np.pi * (fx * i + fy * j) + phase)
    t = np.cos(2.0 * np.pi * (fy * i - fx * j) - phase)
    return (env * (0.5 + 0.25 * s + 0.25 * t)).astype(np.float32)


_PROTOS = None


def prototypes() -> np.ndarray:
    global _PROTOS
    if _PROTOS is None:
        _PROTOS = np.stack([class_prototype(c) for c in range(NUM_CLASSES)])
    return _PROTOS


# --------------------------------------------------------------------------
# samplers (fixed, seed-deterministic draw counts per sample)
# --------------------------------------------------------------------------

NOISE_STD = 0.08
MAX_SHIFT = 2


def _shift(img: np.ndarray, dy: int, dx: int) -> np.ndarray:
    """Shift with zero fill (mirrors the Rust implementation)."""
    out = np.zeros_like(img)
    ys = slice(max(0, dy), min(H, H + dy))
    xs = slice(max(0, dx), min(W, W + dx))
    ys_src = slice(max(0, -dy), min(H, H - dy))
    xs_src = slice(max(0, -dx), min(W, W - dx))
    out[ys, xs] = img[ys_src, xs_src]
    return out


def _add_noise(img: np.ndarray, rng: SplitMix64, std: float) -> np.ndarray:
    noise = rng.normal_array(IMG).reshape(H, W)
    out = (img.astype(np.float64) + std * noise).astype(np.float32)
    return np.clip(out, 0.0, 1.0)


def sample_indomain(seed: int) -> tuple[np.ndarray, int]:
    rng = SplitMix64(seed)
    c = rng.randint(NUM_CLASSES)
    dy = rng.randint(2 * MAX_SHIFT + 1) - MAX_SHIFT
    dx = rng.randint(2 * MAX_SHIFT + 1) - MAX_SHIFT
    img = _shift(prototypes()[c], dy, dx)
    return _add_noise(img, rng, NOISE_STD), c


def sample_ambiguous(seed: int) -> tuple[np.ndarray, int]:
    rng = SplitMix64(seed)
    a = rng.randint(NUM_CLASSES)
    b = (a + 1 + rng.randint(NUM_CLASSES - 1)) % NUM_CLASSES
    lam = np.float32(0.35 + 0.30 * rng.uniform())
    dy = rng.randint(2 * MAX_SHIFT + 1) - MAX_SHIFT
    dx = rng.randint(2 * MAX_SHIFT + 1) - MAX_SHIFT
    proto = (lam * prototypes()[a] + (np.float32(1.0) - lam) * prototypes()[b]).astype(np.float32)
    img = _shift(proto, dy, dx)
    return _add_noise(img, rng, NOISE_STD), int(a)


def sample_ood(seed: int) -> np.ndarray:
    """Texture images: 0=checkerboard, 1=random rectangles, 2=stripes."""
    rng = SplitMix64(seed)
    kind = rng.randint(3)
    img = np.zeros((H, W), dtype=np.float32)
    if kind == 0:
        p = 2 + rng.randint(3)
        hi = np.float32(0.5 + 0.5 * rng.uniform())
        lo = np.float32(0.2 * rng.uniform())
        ii = np.arange(H)[:, None] // p
        jj = np.arange(W)[None, :] // p
        img = np.where((ii + jj) % 2 == 0, hi, lo).astype(np.float32)
    elif kind == 1:
        n_rect = 3 + rng.randint(4)
        for _ in range(n_rect):
            y0 = rng.randint(H - 4)
            x0 = rng.randint(W - 4)
            h = 3 + rng.randint(10)
            w = 3 + rng.randint(10)
            val = np.float32(rng.uniform())
            img[y0 : min(H, y0 + h), x0 : min(W, x0 + w)] = val
    else:
        p = 2 + rng.randint(4)
        horiz = rng.randint(2) == 0
        hi = np.float32(0.4 + 0.6 * rng.uniform())
        k = np.arange(H)[:, None] if horiz else np.arange(W)[None, :]
        img = np.where((k // p) % 2 == 0, hi, np.float32(0.1)).astype(np.float32)
        img = np.broadcast_to(img, (H, W)).copy()
    return _add_noise(img, rng, NOISE_STD)


# --------------------------------------------------------------------------
# dataset assembly
# --------------------------------------------------------------------------

STREAM_INDOMAIN_TRAIN = 1
STREAM_AMBIGUOUS_TRAIN = 2
STREAM_INDOMAIN_TEST = 3
STREAM_AMBIGUOUS_TEST = 4
STREAM_OOD_TEST = 5


def make_split(base_seed: int, stream: int, n: int, kind: str) -> tuple[np.ndarray, np.ndarray]:
    xs = np.empty((n, IMG), dtype=np.float32)
    ys = np.zeros((n,), dtype=np.int32)
    for idx in range(n):
        seed = derive_seed(base_seed, stream, idx)
        if kind == "indomain":
            img, y = sample_indomain(seed)
            ys[idx] = y
        elif kind == "ambiguous":
            img, y = sample_ambiguous(seed)
            ys[idx] = y
        else:
            img = sample_ood(seed)
            ys[idx] = -1
        xs[idx] = img.reshape(-1)
    return xs, ys


def make_dirty_mnist(
    base_seed: int = 2025,
    n_train_clean: int = 6000,
    n_train_amb: int = 2000,
    n_test: int = 1000,
) -> dict[str, np.ndarray]:
    """Full synthetic Dirty-MNIST: train = in-domain + ambiguous (the paper
    trains on MNIST + Ambiguous-MNIST); OOD is test-only."""
    tx1, ty1 = make_split(base_seed, STREAM_INDOMAIN_TRAIN, n_train_clean, "indomain")
    tx2, ty2 = make_split(base_seed, STREAM_AMBIGUOUS_TRAIN, n_train_amb, "ambiguous")
    train_x = np.concatenate([tx1, tx2], axis=0)
    train_y = np.concatenate([ty1, ty2], axis=0)
    # deterministic Fisher-Yates shuffle
    order = np.arange(train_x.shape[0])
    rng = SplitMix64(derive_seed(base_seed, 99, 0))
    for i in range(len(order) - 1, 0, -1):
        j = rng.randint(i + 1)
        order[i], order[j] = order[j], order[i]
    train_x, train_y = train_x[order], train_y[order]

    mx, my = make_split(base_seed, STREAM_INDOMAIN_TEST, n_test, "indomain")
    ax, ay = make_split(base_seed, STREAM_AMBIGUOUS_TEST, n_test, "ambiguous")
    ox, oy = make_split(base_seed, STREAM_OOD_TEST, n_test, "ood")
    return {
        "train_x": train_x,
        "train_y": train_y,
        "test_mnist_x": mx,
        "test_mnist_y": my,
        "test_ambiguous_x": ax,
        "test_ambiguous_y": ay,
        "test_ood_x": ox,
        "test_ood_y": oy,
    }
