"""AOT lowering: JAX models -> HLO *text* artifacts for the Rust runtime.

Emits HLO text, NOT ``.serialize()``: jax >= 0.5 writes HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the published
``xla`` 0.1.6 crate binds) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts:
  model_{arch}_{variant}_b{batch}.hlo.txt
  manifest.json   — entry list: parameter order/shapes the executable
                    expects (input tensor first, then weight tensors)
  goldens.npz     — example input + expected outputs per entry, computed
                    with the trained weights; the Rust integration tests
                    execute the artifact via PJRT and compare.

Variants:
  pfp         single probabilistic forward pass (jnp ops — the serving
              artifact; mathematically identical to the Pallas path)
  pfp_pallas  same graph built from the L1 Pallas kernels (interpret=True
              lowers to plain HLO): proves the L1->L2->L3 path composes
              end-to-end through PJRT.  Kept to small batches — interpret
              mode emits control-flow-heavy HLO that executes slowly.
  det         deterministic forward (posterior means); doubles as the SVI
              executable: the Rust side samples posterior weights and
              feeds them as the weight parameters, one call per sample.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod

BATCHES = (1, 10, 100)
PALLAS_ENTRIES = (("mlp", 1), ("mlp", 10), ("lenet", 1))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def batched_input_shape(arch: str, batch: int) -> tuple[int, ...]:
    return (batch,) + model_mod.INPUT_SHAPES[arch]


def param_specs(arch: str, variant: str):
    """(name, shape) for every weight tensor, in executable order."""
    kind = "pfp" if variant.startswith("pfp") else "det"
    specs = []
    for i, layer in enumerate(model_mod.compute_layers(arch)):
        w = model_mod.weight_shape(layer)
        b = model_mod.bias_shape(layer)
        if kind == "pfp":
            specs += [
                (f"l{i}_w_mu", w), (f"l{i}_w_var", w),
                (f"l{i}_b_mu", b), (f"l{i}_b_var", b),
            ]
        else:
            specs += [(f"l{i}_w", w), (f"l{i}_b", b)]
    return specs


def entry_fn(arch: str, variant: str):
    if variant == "pfp":
        return lambda x, *flat: model_mod.pfp_forward_flat(arch, x, *flat)
    if variant == "pfp_pallas":
        return lambda x, *flat: model_mod.pfp_forward_flat(
            arch, x, *flat, use_pallas=True
        )
    return lambda x, *flat: model_mod.det_forward_flat(arch, x, *flat)


def load_weights(out_dir: str, arch: str):
    z = np.load(os.path.join(out_dir, f"weights_{arch}.npz"))
    return {k: z[k] for k in z.files}


def flat_weights(weights, arch: str, variant: str, calib: float):
    flat = []
    for i, _ in enumerate(model_mod.compute_layers(arch)):
        if variant.startswith("pfp"):
            ws = weights[f"l{i}_w_sigma"]
            bs = weights[f"l{i}_b_sigma"]
            flat += [
                weights[f"l{i}_w_mu"],
                (calib * ws * ws).astype(np.float32),
                weights[f"l{i}_b_mu"],
                (calib * bs * bs).astype(np.float32),
            ]
        else:
            flat += [weights[f"l{i}_w_mu"], weights[f"l{i}_b_mu"]]
    return flat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    with open(os.path.join(out, "metrics.json")) as f:
        metrics = json.load(f)
    data = np.load(os.path.join(out, "data.npz"))

    entries = []
    goldens = {}
    jobs = []
    for arch in ("mlp", "lenet"):
        for batch in BATCHES:
            jobs.append((arch, "pfp", batch))
            jobs.append((arch, "det", batch))
    jobs += [(a, "pfp_pallas", b) for a, b in PALLAS_ENTRIES]

    for arch, variant, batch in jobs:
        name = f"model_{arch}_{variant}_b{batch}"
        in_shape = batched_input_shape(arch, batch)
        specs = param_specs(arch, variant)
        fn = entry_fn(arch, variant)
        arg_specs = [jax.ShapeDtypeStruct(in_shape, jnp.float32)] + [
            jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs
        ]
        print(f"lowering {name} ...")
        lowered = jax.jit(fn).lower(*arg_specs)
        hlo = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out, fname), "w") as f:
            f.write(hlo)

        # golden: real trained weights + a slice of the test set
        calib = metrics[arch]["pfp_calibration_factor"]
        weights = load_weights(out, arch)
        flat = flat_weights(weights, arch, variant, calib)
        x = data["test_mnist_x"][:batch].reshape(in_shape).astype(np.float32)
        outs = jax.jit(fn)(jnp.asarray(x), *[jnp.asarray(a) for a in flat])
        goldens[f"{name}_x"] = x
        out_names = ["mu", "var"] if variant.startswith("pfp") else ["logits"]
        for o_name, o in zip(out_names, outs):
            goldens[f"{name}_{o_name}"] = np.asarray(o, np.float32)

        entries.append(
            {
                "name": name,
                "file": fname,
                "arch": arch,
                "variant": variant,
                "batch": batch,
                "input_shape": list(in_shape),
                "params": [{"name": n, "shape": list(s)} for n, s in specs],
                "outputs": out_names,
                "calibration_factor": calib if variant.startswith("pfp") else None,
            }
        )

    np.savez(os.path.join(out, "goldens.npz"), **goldens)
    manifest = {
        "version": 1,
        "entries": entries,
        "archs": {
            a: {
                "input_shape": list(model_mod.INPUT_SHAPES[a]),
                "layers": model_mod.ARCHS[a],
            }
            for a in ("mlp", "lenet")
        },
        "metrics": metrics,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} HLO artifacts + manifest + goldens to {out}")


if __name__ == "__main__":
    main()
