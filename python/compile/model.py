"""L2: JAX model definitions — PFP / deterministic / SVI-sampled forward.

Architectures (paper Section 4):

* ``mlp``   — 784-100-100-10 with ReLU (the paper's "3-layer MLP";
  Tables 2/4 show Dense 1..3).
* ``lenet`` — LeNet-5 on 28x28: conv 6@5x5 -> ReLU -> maxpool2 ->
  conv 16@5x5 -> ReLU -> maxpool2 -> flatten -> dense 120 -> ReLU ->
  dense 84 -> ReLU -> dense 10.

The PFP forward pass follows the paper's representation discipline
(Section 5): compute layers consume second raw moments and produce
variances; ReLU consumes variances and produces second raw moments;
max-pool consumes and produces variances.  Conversions are inserted by the
executor exactly where representations disagree — the same logic is
mirrored in ``rust/src/model/executor.rs``.

Weights are mean-field Gaussian ``(mu, sigma)`` per tensor; the paper's
*calibration factor* is a global multiplier on the variances applied at
conversion time (Section 4).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref

# --------------------------------------------------------------------------
# architecture specs (mirrored by rust/src/model/{mlp,lenet}.rs)
# --------------------------------------------------------------------------

ARCHS: dict[str, list[dict[str, Any]]] = {
    "mlp": [
        {"kind": "dense", "in": 784, "out": 100},
        {"kind": "relu"},
        {"kind": "dense", "in": 100, "out": 100},
        {"kind": "relu"},
        {"kind": "dense", "in": 100, "out": 10},
    ],
    "lenet": [
        {"kind": "conv", "in_ch": 1, "out_ch": 6, "k": 5},
        {"kind": "relu"},
        {"kind": "maxpool2"},
        {"kind": "conv", "in_ch": 6, "out_ch": 16, "k": 5},
        {"kind": "relu"},
        {"kind": "maxpool2"},
        {"kind": "flatten"},
        {"kind": "dense", "in": 256, "out": 120},
        {"kind": "relu"},
        {"kind": "dense", "in": 120, "out": 84},
        {"kind": "relu"},
        {"kind": "dense", "in": 84, "out": 10},
    ],
}

INPUT_SHAPES = {"mlp": (784,), "lenet": (1, 28, 28)}


def compute_layers(arch: str) -> list[dict[str, Any]]:
    """The parameterised (dense/conv) layers of an architecture, in order."""
    return [l for l in ARCHS[arch] if l["kind"] in ("dense", "conv")]


def weight_shape(layer: dict[str, Any]) -> tuple[int, ...]:
    if layer["kind"] == "dense":
        return (layer["out"], layer["in"])
    return (layer["out_ch"], layer["in_ch"], layer["k"], layer["k"])


def bias_shape(layer: dict[str, Any]) -> tuple[int, ...]:
    return (layer["out"],) if layer["kind"] == "dense" else (layer["out_ch"],)


# --------------------------------------------------------------------------
# parameter init (variational posterior, paper Section 4)
# --------------------------------------------------------------------------

def softplus(x):
    return jnp.logaddexp(x, 0.0)


def inv_softplus(y: float) -> float:
    return float(math.log(math.expm1(y)))


def init_params(arch: str, key, mu_std: float = 0.08, sigma_init: float = 1e-3):
    """Mean-field Gaussian posterior init: mu ~ N(0, mu_std^2) (fan-in
    scaled for conv), rho such that sigma = softplus(rho) = sigma_init."""
    params = []
    rho0 = inv_softplus(sigma_init)
    for layer in compute_layers(arch):
        key, k1 = jax.random.split(key)
        wshape = weight_shape(layer)
        fan_in = int(jnp.prod(jnp.array(wshape[1:])))
        std = min(mu_std, 1.6 / math.sqrt(fan_in))
        params.append(
            {
                "w_mu": std * jax.random.normal(k1, wshape, jnp.float32),
                "w_rho": jnp.full(wshape, rho0, jnp.float32),
                "b_mu": jnp.zeros(bias_shape(layer), jnp.float32),
                "b_rho": jnp.full(bias_shape(layer), rho0, jnp.float32),
            }
        )
    return params


def params_sigma(params):
    """(mu, sigma) view of a (mu, rho) parameter pytree."""
    return [
        {
            "w_mu": p["w_mu"],
            "w_sigma": softplus(p["w_rho"]),
            "b_mu": p["b_mu"],
            "b_sigma": softplus(p["b_rho"]),
        }
        for p in params
    ]


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------

def det_forward(arch: str, weights, x):
    """Deterministic forward pass. ``weights`` = [(w, b), ...]."""
    i = 0
    h = x
    for layer in ARCHS[arch]:
        kind = layer["kind"]
        if kind == "dense":
            w, b = weights[i]
            h = ref.det_dense(h, w, b)
            i += 1
        elif kind == "conv":
            w, b = weights[i]
            h = ref.det_conv2d(h, w, b)
            i += 1
        elif kind == "relu":
            h = ref.det_relu(h)
        elif kind == "maxpool2":
            h = ref.det_maxpool2(h)
        elif kind == "flatten":
            h = h.reshape(h.shape[0], -1)
    return h


def svi_sample_weights(params_sig, key):
    """One posterior weight sample (reparameterisation trick)."""
    out = []
    for p in params_sig:
        key, k1, k2 = jax.random.split(key, 3)
        w = p["w_mu"] + p["w_sigma"] * jax.random.normal(k1, p["w_mu"].shape)
        b = p["b_mu"] + p["b_sigma"] * jax.random.normal(k2, p["b_mu"].shape)
        out.append((w, b))
    return out


def svi_forward(arch: str, params_sig, x, key):
    """One SVI predictive sample: sample weights, one deterministic pass."""
    return det_forward(arch, svi_sample_weights(params_sig, key), x)


def pfp_forward(arch: str, params_sig, x, calib: float = 1.0,
                use_pallas: bool = False):
    """Single probabilistic forward pass -> (mu_logits, var_logits).

    ``calib`` is the paper's calibration factor: a global reweighting of
    the posterior weight variances when converting SVI -> PFP.
    ``use_pallas=True`` routes dense/conv/relu/maxpool through the L1
    Pallas kernels; ``False`` uses the pure-jnp reference ops (identical
    math — asserted by tests — and the form AOT-lowered for serving).
    """
    K = kernels if use_pallas else ref
    i = 0
    mu, aux = x, None  # aux is var or e2 depending on rep
    rep = "det"
    for layer in ARCHS[arch]:
        kind = layer["kind"]
        if kind in ("dense", "conv"):
            p = params_sig[i]
            i += 1
            w_mu = p["w_mu"]
            w_var = calib * p["w_sigma"] * p["w_sigma"]
            b_mu = p["b_mu"]
            b_var = calib * p["b_sigma"] * p["b_sigma"]
            if kind == "dense":
                first = kernels.pfp_dense_first if use_pallas else ref.pfp_dense_first
                joint = kernels.pfp_dense_joint if use_pallas else ref.pfp_dense_joint
            else:
                first = kernels.pfp_conv2d_first if use_pallas else ref.pfp_conv2d_first
                joint = kernels.pfp_conv2d_joint if use_pallas else ref.pfp_conv2d_joint
            if rep == "det":
                mu, aux = first(mu, w_mu, w_var, b_mu, b_var)
            else:
                if rep == "var":
                    aux = ref.var_to_e2(mu, aux)  # conversion layer
                w_e2 = w_mu * w_mu + w_var
                mu, aux = joint(mu, aux, w_mu, w_e2, b_mu, b_var)
            rep = "var"
        elif kind == "relu":
            assert rep == "var"
            relu = kernels.pfp_relu if use_pallas else ref.pfp_relu
            mu, aux = relu(mu, aux)
            rep = "e2"
        elif kind == "maxpool2":
            if rep == "e2":
                aux = ref.e2_to_var(mu, aux)
            pool = kernels.pfp_maxpool2 if use_pallas else ref.pfp_maxpool2
            mu, aux = pool(mu, aux)
            rep = "var"
        elif kind == "flatten":
            mu = mu.reshape(mu.shape[0], -1)
            aux = aux.reshape(aux.shape[0], -1)
    if rep == "e2":
        aux = ref.e2_to_var(mu, aux)
    return mu, aux


# --------------------------------------------------------------------------
# flat parameter packing for AOT (manifest order must match the Rust side)
# --------------------------------------------------------------------------

def flat_param_names(arch: str, variant: str) -> list[str]:
    """Parameter-tensor names in the order the AOT executable expects them
    after the input tensor.  pfp: (w_mu, w_var, b_mu, b_var) per compute
    layer; det (also used for SVI samples): (w, b) per compute layer."""
    names = []
    for i, _ in enumerate(compute_layers(arch)):
        if variant == "pfp":
            names += [f"l{i}_w_mu", f"l{i}_w_var", f"l{i}_b_mu", f"l{i}_b_var"]
        else:
            names += [f"l{i}_w", f"l{i}_b"]
    return names


def pfp_forward_flat(arch: str, x, *flat, use_pallas: bool = False):
    """PFP forward over a flat (w_mu, w_var, b_mu, b_var)* argument list —
    the AOT entry point (calibration is pre-applied to w_var by the
    caller/loader)."""
    params = []
    for i in range(0, len(flat), 4):
        w_mu, w_var, b_mu, b_var = flat[i : i + 4]
        params.append(
            {
                "w_mu": w_mu,
                "w_sigma": jnp.sqrt(w_var),
                "b_mu": b_mu,
                "b_sigma": jnp.sqrt(b_var),
            }
        )
    return pfp_forward(arch, params, x, calib=1.0, use_pallas=use_pallas)


def det_forward_flat(arch: str, x, *flat):
    """Deterministic forward over a flat (w, b)* argument list — the AOT
    entry point for both the deterministic baseline and SVI samples."""
    weights = [(flat[i], flat[i + 1]) for i in range(0, len(flat), 2)]
    return (det_forward(arch, weights, x),)
