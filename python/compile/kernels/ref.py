"""Pure-jnp reference implementations of every PFP operator.

This module is the correctness oracle: each Pallas kernel in this package
is checked against the function of the same name here (pytest + hypothesis,
``python/tests/test_kernels.py``), and the Rust native operator library
checks against goldens computed from these functions.

Conventions (paper Section 5, "Variance and Second Raw Moment"):

* compute layers (dense / conv) consume activation **second raw moments**
  ``E[x^2]`` (plus means) and weight second raw moments ``E[w^2]``, and
  produce pre-activation **variances** (Eq. 12);
* activation functions (ReLU) consume variances and produce second raw
  moments (Eqs. 8, 9);
* max-pool consumes and produces variances;
* the first layer sees a deterministic input: feeding ``x_e2 = x^2`` and
  ``w_e2 = mu_w^2 + sigma_w^2`` into the generic dense reduces Eq. 12 to
  Eq. 13 exactly, which is how both the JAX and Rust stacks realise it.

Shapes: dense weights are ``[out, in]`` (so the matmul is ``x @ w.T``),
conv weights ``[O, I, kh, kw]``, activations NCHW.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

INV_SQRT_2PI = 0.3989422804014327  # 1/sqrt(2*pi)

def erf(x):
    """Abramowitz & Stegun 7.1.26 rational erf approximation (|err|<=1.5e-7).

    Used instead of ``jax.scipy.special.erf`` so the AOT-lowered HLO
    contains only classic opcodes (XLA 0.5.1's HLO text parser predates the
    ``erf`` instruction) — and so the JAX stack shares the *exact* erf
    formula with the Rust operator library (``rust/src/ops/erf.rs``).
    """
    p = 0.3275911
    a1, a2, a3, a4, a5 = (0.254829592, -0.284496736, 1.421413741,
                          -1.453152027, 1.061405429)
    s = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + p * ax)
    poly = ((((a5 * t + a4) * t + a3) * t + a2) * t + a1) * t
    return s * (1.0 - poly * jnp.exp(-ax * ax))



# --------------------------------------------------------------------------
# dense
# --------------------------------------------------------------------------

def pfp_dense_joint(x_mu, x_e2, w_mu, w_e2, b_mu=None, b_var=None):
    """Joint mean+variance PFP dense, second-raw-moment form (Eq. 12).

    a_mu[m,n]  = sum_k x_mu[m,k] * w_mu[n,k]            (Eq. 4)
    a_var[m,n] = sum_k E[w^2][n,k]*E[x^2][m,k] - (w_mu[n,k]*x_mu[m,k])^2
    """
    a_mu = x_mu @ w_mu.T
    a_var = x_e2 @ w_e2.T - (x_mu * x_mu) @ (w_mu * w_mu).T
    if b_mu is not None:
        a_mu = a_mu + b_mu
    if b_var is not None:
        a_var = a_var + b_var
    return a_mu, jnp.maximum(a_var, 0.0)


def pfp_dense_varform(x_mu, x_var, w_mu, w_var, b_mu=None, b_var=None):
    """Variance-form PFP dense (Eq. 7):
    a_var = sum_k  sigma_w^2 * E[x^2] + mu_w^2 * sigma_x^2 .
    Mathematically identical to :func:`pfp_dense_joint` with
    ``x_e2 = x_mu^2 + x_var`` and ``w_e2 = w_mu^2 + w_var``."""
    x_e2 = x_mu * x_mu + x_var
    a_mu = x_mu @ w_mu.T
    a_var = x_e2 @ w_var.T + x_var @ (w_mu * w_mu).T
    if b_mu is not None:
        a_mu = a_mu + b_mu
    if b_var is not None:
        a_var = a_var + b_var
    return a_mu, jnp.maximum(a_var, 0.0)


def pfp_dense_first(x, w_mu, w_var, b_mu=None, b_var=None):
    """First-layer dense with deterministic input (Eq. 13)."""
    a_mu = x @ w_mu.T
    a_var = (x * x) @ w_var.T
    if b_mu is not None:
        a_mu = a_mu + b_mu
    if b_var is not None:
        a_var = a_var + b_var
    return a_mu, jnp.maximum(a_var, 0.0)


def pfp_dense_separate(x_mu, x_e2, w_mu, w_e2, b_mu=None, b_var=None):
    """Separate mean / variance paths (the paper's Fig. 5 baseline): the
    same math as :func:`pfp_dense_joint` but without sharing the x tiles
    between the two paths (models the two-operator TVM split)."""
    a_mu = x_mu @ w_mu.T
    mean_sq = (x_mu * x_mu) @ (w_mu * w_mu).T  # recomputed, no reuse
    a_var = x_e2 @ w_e2.T - mean_sq
    if b_mu is not None:
        a_mu = a_mu + b_mu
    if b_var is not None:
        a_var = a_var + b_var
    return a_mu, jnp.maximum(a_var, 0.0)


# --------------------------------------------------------------------------
# ReLU moment matching (Eqs. 8, 9)
# --------------------------------------------------------------------------

def pfp_relu(a_mu, a_var, eps: float = 1e-12):
    """Moment-matched ReLU over a Gaussian pre-activation.

    Input (mu, var); output (mu', E[x'^2]) — second raw moment by design.
    """
    var = jnp.maximum(a_var, eps)
    std = jnp.sqrt(var)
    z = a_mu / (std * jnp.sqrt(2.0))
    cdf_term = 0.5 * (1.0 + erf(z))                 # Phi(mu/sigma)
    pdf_term = std * INV_SQRT_2PI * jnp.exp(-(a_mu * a_mu) / (2.0 * var))
    mu_out = a_mu * cdf_term + pdf_term
    e2_out = (var + a_mu * a_mu) * cdf_term + a_mu * pdf_term
    return mu_out, jnp.maximum(e2_out, 0.0)


def relu_mc(a_mu, a_var, key, n: int = 200000):
    """Monte-Carlo ground truth for the ReLU moment matching (test-only)."""
    s = a_mu + jnp.sqrt(jnp.maximum(a_var, 0.0)) * jax.random.normal(
        key, (n,) + a_mu.shape
    )
    r = jnp.maximum(s, 0.0)
    return r.mean(axis=0), (r * r).mean(axis=0)


# --------------------------------------------------------------------------
# Gaussian max (max-pool building block)
# --------------------------------------------------------------------------

def gaussian_max(mu1, var1, mu2, var2, eps: float = 1e-12):
    """Moment-matched max of two independent Gaussians (Roth 2021).

    theta = sqrt(var1 + var2); alpha = (mu1 - mu2)/theta
    E[max]   = mu1*Phi(alpha) + mu2*Phi(-alpha) + theta*phi(alpha)
    E[max^2] = (mu1^2+var1)*Phi(alpha) + (mu2^2+var2)*Phi(-alpha)
               + (mu1+mu2)*theta*phi(alpha)
    Returns (mean, variance).
    """
    theta = jnp.sqrt(jnp.maximum(var1 + var2, eps))
    alpha = (mu1 - mu2) / theta
    cdf = 0.5 * (1.0 + erf(alpha / jnp.sqrt(2.0)))
    pdf = INV_SQRT_2PI * jnp.exp(-0.5 * alpha * alpha)
    m = mu1 * cdf + mu2 * (1.0 - cdf) + theta * pdf
    e2 = (
        (mu1 * mu1 + var1) * cdf
        + (mu2 * mu2 + var2) * (1.0 - cdf)
        + (mu1 + mu2) * theta * pdf
    )
    return m, jnp.maximum(e2 - m * m, 0.0)


def pfp_maxpool2(mu, var):
    """2x2/stride-2 PFP max-pool over NCHW Gaussian activations.

    Consumes and produces (mean, variance) — paper Section 5.  Pairwise
    moment-matched Gaussian max: rows first, then columns.
    """
    m00, m01 = mu[..., 0::2, 0::2], mu[..., 0::2, 1::2]
    m10, m11 = mu[..., 1::2, 0::2], mu[..., 1::2, 1::2]
    v00, v01 = var[..., 0::2, 0::2], var[..., 0::2, 1::2]
    v10, v11 = var[..., 1::2, 0::2], var[..., 1::2, 1::2]
    ma, va = gaussian_max(m00, v00, m01, v01)
    mb, vb = gaussian_max(m10, v10, m11, v11)
    return gaussian_max(ma, va, mb, vb)


def pfp_maxpool_generic(mu, var, k: int = 2, stride: int = 2):
    """Generic reduction formulation (the paper's slow baseline): iterated
    pairwise Gaussian max over an arbitrary k x k window."""
    n, c, h, w = mu.shape
    oh, ow = (h - k) // stride + 1, (w - k) // stride + 1
    out_m = None
    out_v = None
    for di in range(k):
        for dj in range(k):
            sm = mu[..., di : di + stride * (oh - 1) + 1 : stride,
                    dj : dj + stride * (ow - 1) + 1 : stride]
            sv = var[..., di : di + stride * (oh - 1) + 1 : stride,
                     dj : dj + stride * (ow - 1) + 1 : stride]
            if out_m is None:
                out_m, out_v = sm, sv
            else:
                out_m, out_v = gaussian_max(out_m, out_v, sm, sv)
    return out_m, out_v


# --------------------------------------------------------------------------
# conv2d (moment algebra identical to dense, over image patches)
# --------------------------------------------------------------------------

def _conv(x, w, padding):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def pfp_conv2d_joint(x_mu, x_e2, w_mu, w_e2, b_mu=None, b_var=None, padding="VALID"):
    """PFP conv2d, second-raw-moment form (Eq. 12 over receptive fields)."""
    a_mu = _conv(x_mu, w_mu, padding)
    a_var = _conv(x_e2, w_e2, padding) - _conv(x_mu * x_mu, w_mu * w_mu, padding)
    if b_mu is not None:
        a_mu = a_mu + b_mu[None, :, None, None]
    if b_var is not None:
        a_var = a_var + b_var[None, :, None, None]
    return a_mu, jnp.maximum(a_var, 0.0)


def pfp_conv2d_first(x, w_mu, w_var, b_mu=None, b_var=None, padding="VALID"):
    """First-layer conv with deterministic input (Eq. 13)."""
    a_mu = _conv(x, w_mu, padding)
    a_var = _conv(x * x, w_var, padding)
    if b_mu is not None:
        a_mu = a_mu + b_mu[None, :, None, None]
    if b_var is not None:
        a_var = a_var + b_var[None, :, None, None]
    return a_mu, jnp.maximum(a_var, 0.0)


# --------------------------------------------------------------------------
# deterministic & conversion helpers
# --------------------------------------------------------------------------

def det_dense(x, w, b=None):
    y = x @ w.T
    return y + b if b is not None else y


def det_conv2d(x, w, b=None, padding="VALID"):
    y = _conv(x, w, padding)
    return y + b[None, :, None, None] if b is not None else y


def det_relu(x):
    return jnp.maximum(x, 0.0)


def det_maxpool2(x):
    n, c, h, w = x.shape
    return jnp.max(x.reshape(n, c, h // 2, 2, w // 2, 2), axis=(3, 5))


def var_to_e2(mu, var):
    return mu * mu + var


def e2_to_var(mu, e2):
    return jnp.maximum(e2 - mu * mu, 0.0)
