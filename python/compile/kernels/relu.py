"""Pallas PFP ReLU kernel (Eqs. 8, 9): moment-matched Gaussian ReLU.

Elementwise but, as the paper's Fig. 6 shows, far from trivial at runtime:
erf + exp per element.  Consumes (mean, variance), produces
(mean, second raw moment).  One grid program per row-block keeps the VPU
busy on contiguous lanes; the whole tuple is produced jointly so the
cdf/pdf sub-terms are shared between the two outputs (joint-operator rule).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import erf

INV_SQRT_2PI = 0.3989422804014327


def _relu_kernel(mu_ref, var_ref, out_mu_ref, out_e2_ref):
    mu = mu_ref[...]
    var = jnp.maximum(var_ref[...], 1e-12)
    std = jnp.sqrt(var)
    cdf = 0.5 * (1.0 + erf(mu / (std * jnp.sqrt(2.0))))
    pdf = std * INV_SQRT_2PI * jnp.exp(-(mu * mu) / (2.0 * var))
    out_mu_ref[...] = mu * cdf + pdf
    out_e2_ref[...] = jnp.maximum((var + mu * mu) * cdf + mu * pdf, 0.0)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def pfp_relu(a_mu, a_var, block_rows: int = 8):
    """Moment-matched ReLU. Accepts any shape; flattens to 2D internally."""
    shape = a_mu.shape
    flat_mu = a_mu.reshape(shape[0], -1)
    flat_var = a_var.reshape(shape[0], -1)
    m, n = flat_mu.shape
    bm = min(block_rows, m)
    # pad rows to a multiple of the block
    mp = (m + bm - 1) // bm * bm
    if mp != m:
        flat_mu = jnp.pad(flat_mu, ((0, mp - m), (0, 0)))
        flat_var = jnp.pad(flat_var, ((0, mp - m), (0, 0)))
    mu, e2 = pl.pallas_call(
        _relu_kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, n), jnp.float32),
            jax.ShapeDtypeStruct((mp, n), jnp.float32),
        ],
        interpret=True,
    )(flat_mu, flat_var)
    return mu[:m].reshape(shape), e2[:m].reshape(shape)
