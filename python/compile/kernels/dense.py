"""Pallas PFP dense kernels (L1).

The paper's hottest operator: the joint mean+variance probabilistic dense
layer in second-raw-moment form (Eq. 12).  TPU adaptation of the paper's
ARM/TVM schedule (DESIGN.md §Hardware-Adaptation):

* the TVM loop tiling over (batch, out-features) becomes the Pallas grid
  with (block_m, block_n) output tiles — the BlockSpec index maps express
  the HBM->VMEM schedule TVM expressed with loop transforms;
* the "joint operator" data reuse (paper Fig. 5) is realised by computing
  both the mean matmul and the two variance-path matmuls inside one grid
  program while the x-tiles are resident in VMEM;
* both accumulations are plain f32 matmuls, i.e. MXU-shaped work.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is asserted against ``ref.py`` and real-TPU
performance is estimated in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad2(a, m_to: int, k_to: int):
    m, k = a.shape
    if m == m_to and k == k_to:
        return a
    return jnp.pad(a, ((0, m_to - m), (0, k_to - k)))


# --------------------------------------------------------------------------
# joint kernel: one grid program computes the mean tile and the variance
# tile, sharing the x_mu tile between the mean matmul and the subtraction
# term of Eq. 12.
# --------------------------------------------------------------------------

def _joint_kernel(x_mu_ref, x_e2_ref, w_mu_ref, w_e2_ref, mu_ref, var_ref):
    xm = x_mu_ref[...]
    xe = x_e2_ref[...]
    wm = w_mu_ref[...]
    we = w_e2_ref[...]
    mu = jnp.dot(xm, wm.T, preferred_element_type=jnp.float32)
    # Eq. 12: var = E[x^2] E[w^2] - (mu_x mu_w)^2, summed over k.
    cross = jnp.dot(xm * xm, (wm * wm).T, preferred_element_type=jnp.float32)
    var = jnp.dot(xe, we.T, preferred_element_type=jnp.float32) - cross
    mu_ref[...] = mu
    var_ref[...] = jnp.maximum(var, 0.0)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def pfp_dense_joint(x_mu, x_e2, w_mu, w_e2, b_mu=None, b_var=None,
                    block_m: int = 32, block_n: int = 32):
    """Joint PFP dense (Eq. 12). x: [M,K]; w: [N,K] -> ([M,N], [M,N])."""
    m, k = x_mu.shape
    n, _ = w_mu.shape
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 8))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), k
    xm = _pad2(x_mu, mp, kp)
    xe = _pad2(x_e2, mp, kp)
    wm = _pad2(w_mu, np_, kp)
    we = _pad2(w_e2, np_, kp)
    grid = (mp // bm, np_ // bn)
    mu, var = pl.pallas_call(
        _joint_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, kp), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, kp), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=True,
    )(xm, xe, wm, we)
    mu, var = mu[:m, :n], var[:m, :n]
    if b_mu is not None:
        mu = mu + b_mu
    if b_var is not None:
        var = var + b_var
    return mu, var


# --------------------------------------------------------------------------
# separate kernels (Fig. 5 baseline): two pallas_calls, no tile sharing.
# --------------------------------------------------------------------------

def _mean_kernel(x_mu_ref, w_mu_ref, mu_ref):
    mu_ref[...] = jnp.dot(x_mu_ref[...], w_mu_ref[...].T,
                          preferred_element_type=jnp.float32)


def _var_kernel(x_mu_ref, x_e2_ref, w_mu_ref, w_e2_ref, var_ref):
    xm = x_mu_ref[...]
    cross = jnp.dot(xm * xm, (w_mu_ref[...] * w_mu_ref[...]).T,
                    preferred_element_type=jnp.float32)
    var = jnp.dot(x_e2_ref[...], w_e2_ref[...].T,
                  preferred_element_type=jnp.float32) - cross
    var_ref[...] = jnp.maximum(var, 0.0)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def pfp_dense_separate(x_mu, x_e2, w_mu, w_e2, b_mu=None, b_var=None,
                       block_m: int = 32, block_n: int = 32):
    """Separate mean / variance PFP dense: same math as the joint kernel but
    issued as two pallas_calls (the paper's "one operator = one compute
    rule" TVM split).  Exists to reproduce Fig. 5."""
    m, k = x_mu.shape
    n, _ = w_mu.shape
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 8))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), k
    xm = _pad2(x_mu, mp, kp)
    xe = _pad2(x_e2, mp, kp)
    wm = _pad2(w_mu, np_, kp)
    we = _pad2(w_e2, np_, kp)
    grid = (mp // bm, np_ // bn)
    x_spec = pl.BlockSpec((bm, kp), lambda i, j: (i, 0))
    w_spec = pl.BlockSpec((bn, kp), lambda i, j: (j, 0))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    o_shape = jax.ShapeDtypeStruct((mp, np_), jnp.float32)
    mu = pl.pallas_call(
        _mean_kernel, grid=grid, in_specs=[x_spec, w_spec],
        out_specs=o_spec, out_shape=o_shape, interpret=True,
    )(xm, wm)
    var = pl.pallas_call(
        _var_kernel, grid=grid, in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=o_spec, out_shape=o_shape, interpret=True,
    )(xm, xe, wm, we)
    mu, var = mu[:m, :n], var[:m, :n]
    if b_mu is not None:
        mu = mu + b_mu
    if b_var is not None:
        var = var + b_var
    return mu, var


# --------------------------------------------------------------------------
# variance-form kernel (Eq. 7): used when the producer hands us variances.
# --------------------------------------------------------------------------

def _varform_kernel(x_mu_ref, x_var_ref, w_mu_ref, w_var_ref, mu_ref, var_ref):
    xm = x_mu_ref[...]
    xv = x_var_ref[...]
    wm = w_mu_ref[...]
    wv = w_var_ref[...]
    mu = jnp.dot(xm, wm.T, preferred_element_type=jnp.float32)
    xe = xm * xm + xv
    var = (
        jnp.dot(xe, wv.T, preferred_element_type=jnp.float32)
        + jnp.dot(xv, (wm * wm).T, preferred_element_type=jnp.float32)
    )
    mu_ref[...] = mu
    var_ref[...] = jnp.maximum(var, 0.0)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def pfp_dense_varform(x_mu, x_var, w_mu, w_var, b_mu=None, b_var=None,
                      block_m: int = 32, block_n: int = 32):
    """Variance-form PFP dense (Eq. 7), joint kernel."""
    m, k = x_mu.shape
    n, _ = w_mu.shape
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 8))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), k
    xm = _pad2(x_mu, mp, kp)
    xv = _pad2(x_var, mp, kp)
    wm = _pad2(w_mu, np_, kp)
    wv = _pad2(w_var, np_, kp)
    grid = (mp // bm, np_ // bn)
    mu, var = pl.pallas_call(
        _varform_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, kp), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, kp), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=True,
    )(xm, xv, wm, wv)
    mu, var = mu[:m, :n], var[:m, :n]
    if b_mu is not None:
        mu = mu + b_mu
    if b_var is not None:
        var = var + b_var
    return mu, var


def pfp_dense_first(x, w_mu, w_var, b_mu=None, b_var=None,
                    block_m: int = 32, block_n: int = 32):
    """First-layer dense with deterministic input (Eq. 13): the generic
    joint kernel with ``x_e2 = x^2`` and ``w_e2 = mu_w^2 + sigma_w^2``
    reduces exactly to Eq. 13 (the mu_w^2 x^2 terms cancel)."""
    return pfp_dense_joint(
        x, x * x, w_mu, w_mu * w_mu + w_var, b_mu, b_var,
        block_m=block_m, block_n=block_n,
    )
