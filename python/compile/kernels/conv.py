"""Pallas PFP conv2d: the moment algebra of Eq. 12 over image patches.

The conv is lowered to the *same* joint matmul kernel as the dense layer
(im2col): patches of (mu_x, E[x^2]) are extracted with
``conv_general_dilated_patches`` and fed to the blocked Pallas joint-dense
kernel, so the conv inherits the joint-operator tile reuse.  This mirrors
how the paper's TVM conv operators share the dense schedule machinery.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .dense import pfp_dense_joint


def _patches(x, kh: int, kw: int, padding: str):
    """[N, C, H, W] -> [N*OH*OW, C*kh*kw] patch matrix."""
    p = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (1, 1), padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # [N, C*kh*kw, OH, OW]
    n, ckk, oh, ow = p.shape
    return p.transpose(0, 2, 3, 1).reshape(n * oh * ow, ckk), (n, oh, ow)


@functools.partial(jax.jit, static_argnames=("padding", "block_m", "block_n"))
def pfp_conv2d_joint(x_mu, x_e2, w_mu, w_e2, b_mu=None, b_var=None,
                     padding: str = "VALID",
                     block_m: int = 64, block_n: int = 16):
    """PFP conv2d in second-raw-moment form.  w: [O, I, kh, kw]."""
    o, i, kh, kw = w_mu.shape
    pm, (n, oh, ow) = _patches(x_mu, kh, kw, padding)
    pe, _ = _patches(x_e2, kh, kw, padding)
    wm = w_mu.reshape(o, i * kh * kw)
    we = w_e2.reshape(o, i * kh * kw)
    mu, var = pfp_dense_joint(pm, pe, wm, we, block_m=block_m, block_n=block_n)
    mu = mu.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)
    var = var.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)
    if b_mu is not None:
        mu = mu + b_mu[None, :, None, None]
    if b_var is not None:
        var = var + b_var[None, :, None, None]
    return mu, var


def pfp_conv2d_first(x, w_mu, w_var, b_mu=None, b_var=None,
                     padding: str = "VALID",
                     block_m: int = 64, block_n: int = 16):
    """First-layer conv with deterministic input (Eq. 13) via the generic
    joint kernel (see dense.pfp_dense_first for the algebra)."""
    return pfp_conv2d_joint(
        x, x * x, w_mu, w_mu * w_mu + w_var, b_mu, b_var,
        padding=padding, block_m=block_m, block_n=block_n,
    )
