"""Pallas PFP max-pool kernel: 2x2/stride-2 moment-matched Gaussian max.

The paper (Table 3) contrasts a generic-reduction max-pool with a
vectorized fixed-k implementation.  This kernel is the vectorized k=2
variant: the three pairwise Gaussian-max moment matches for a 2x2 window
are fused into a single grid program over the four strided views, sharing
the erf/exp sub-terms of each pair.  Consumes and produces variances.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import erf

INV_SQRT_2PI = 0.3989422804014327


def _gmax(mu1, var1, mu2, var2):
    theta = jnp.sqrt(jnp.maximum(var1 + var2, 1e-12))
    alpha = (mu1 - mu2) / theta
    cdf = 0.5 * (1.0 + erf(alpha / jnp.sqrt(2.0)))
    pdf = INV_SQRT_2PI * jnp.exp(-0.5 * alpha * alpha)
    m = mu1 * cdf + mu2 * (1.0 - cdf) + theta * pdf
    e2 = (
        (mu1 * mu1 + var1) * cdf
        + (mu2 * mu2 + var2) * (1.0 - cdf)
        + (mu1 + mu2) * theta * pdf
    )
    return m, jnp.maximum(e2 - m * m, 0.0)


def _pool_kernel(m00, v00, m01, v01, m10, v10, m11, v11, out_mu, out_var):
    ma, va = _gmax(m00[...], v00[...], m01[...], v01[...])
    mb, vb = _gmax(m10[...], v10[...], m11[...], v11[...])
    mo, vo = _gmax(ma, va, mb, vb)
    out_mu[...] = mo
    out_var[...] = vo


@jax.jit
def pfp_maxpool2(mu, var):
    """2x2 stride-2 PFP max-pool over NCHW (mean, variance) tensors."""
    n, c, h, w = mu.shape
    oh, ow = h // 2, w // 2
    views = []
    for di in (0, 1):
        for dj in (0, 1):
            views.append(mu[..., di::2, dj::2].reshape(n, c * oh * ow))
            views.append(var[..., di::2, dj::2].reshape(n, c * oh * ow))
    flat = c * oh * ow
    spec = pl.BlockSpec((1, flat), lambda i: (i, 0))
    out_mu, out_var = pl.pallas_call(
        _pool_kernel,
        grid=(n,),
        in_specs=[spec] * 8,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n, flat), jnp.float32),
            jax.ShapeDtypeStruct((n, flat), jnp.float32),
        ],
        interpret=True,
    )(*views)
    return out_mu.reshape(n, c, oh, ow), out_var.reshape(n, c, oh, ow)
