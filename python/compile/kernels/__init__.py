"""L1 Pallas kernels for the Probabilistic Forward Pass + pure-jnp oracle.

Every kernel is checked against :mod:`compile.kernels.ref` by
``python/tests/test_kernels.py`` (hypothesis sweeps shapes and values).
"""

from . import ref  # noqa: F401
from .dense import (  # noqa: F401
    pfp_dense_first,
    pfp_dense_joint,
    pfp_dense_separate,
    pfp_dense_varform,
)
from .relu import pfp_relu  # noqa: F401
from .maxpool import pfp_maxpool2  # noqa: F401
from .conv import pfp_conv2d_first, pfp_conv2d_joint  # noqa: F401
