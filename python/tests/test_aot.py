"""AOT lowering: HLO text round-trips and manifest consistency.

Self-contained (does not require `make artifacts` to have run): lowers a
small entry and checks the HLO text parses structurally; the full artifact
set is validated end-to-end by the Rust integration tests.
"""

import numpy as np
import pytest

# Heavyweight dep is optional so the suite stays green offline.
jax = pytest.importorskip("jax", reason="jax not installed (offline CI)")

import jax.numpy as jnp

from compile import aot, model as model_mod

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("arch,variant,batch", [
    ("mlp", "pfp", 1),
    ("mlp", "det", 10),
    ("lenet", "pfp", 1),
])
def test_lowering_produces_hlo_text(arch, variant, batch):
    in_shape = aot.batched_input_shape(arch, batch)
    specs = aot.param_specs(arch, variant)
    fn = aot.entry_fn(arch, variant)
    args = [jax.ShapeDtypeStruct(in_shape, jnp.float32)] + [
        jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs
    ]
    hlo = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert hlo.startswith("HloModule")
    assert "ENTRY" in hlo
    # one HLO parameter per tensor (input + weights)
    assert hlo.count("parameter(") == 1 + len(specs)


def test_pallas_lowering_is_plain_hlo():
    """interpret=True must not leave custom-calls the CPU client can't run."""
    in_shape = aot.batched_input_shape("mlp", 1)
    specs = aot.param_specs("mlp", "pfp_pallas")
    fn = aot.entry_fn("mlp", "pfp_pallas")
    args = [jax.ShapeDtypeStruct(in_shape, jnp.float32)] + [
        jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs
    ]
    hlo = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "mosaic" not in hlo.lower()


def test_param_specs_match_model():
    specs = aot.param_specs("lenet", "pfp")
    names = model_mod.flat_param_names("lenet", "pfp")
    assert [n for n, _ in specs] == names
    # first conv weights
    assert specs[0][1] == (6, 1, 5, 5)
    # final dense
    assert specs[-4][1] == (10, 84)


def test_det_and_pfp_entry_consistency():
    """det entry over posterior means == PFP means in the zero-variance
    limit (cross-checks the two AOT graphs)."""
    arch = "mlp"
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(size=(2, 784)).astype(np.float32))
    p = model_mod.params_sigma(
        model_mod.init_params(arch, jax.random.PRNGKey(0), sigma_init=1e-7)
    )
    det_flat, pfp_flat = [], []
    for layer in p:
        det_flat += [layer["w_mu"], layer["b_mu"]]
        pfp_flat += [layer["w_mu"], layer["w_sigma"] ** 2,
                     layer["b_mu"], layer["b_sigma"] ** 2]
    (det_out,) = model_mod.det_forward_flat(arch, x, *det_flat)
    pfp_mu, _ = model_mod.pfp_forward_flat(arch, x, *pfp_flat)
    np.testing.assert_allclose(np.asarray(det_out), np.asarray(pfp_mu),
                               atol=1e-3, rtol=1e-3)
