"""Synthetic Dirty-MNIST generator: determinism, structure, separability."""

import numpy as np
import pytest

from compile import data as D


def test_splitmix_vectorised_equals_scalar():
    rng_a = D.SplitMix64(12345)
    seq_scalar = [rng_a.next_u64() for _ in range(64)]
    rng_b = D.SplitMix64(12345)
    seq_vec = rng_b.next_array(64).tolist()
    assert seq_scalar == seq_vec
    assert rng_a.state == rng_b.state


def test_splitmix_known_values():
    """Pinned outputs — the Rust SplitMix64 asserts the same constants."""
    rng = D.SplitMix64(0)
    vals = [rng.next_u64() for _ in range(3)]
    assert vals[0] == 0xE220A8397B1DCDAF
    assert vals[1] == 0x6E789E6AA1B965F4
    assert vals[2] == 0x06C45D188009454F


def test_uniform_range_and_determinism():
    rng = D.SplitMix64(7)
    us = rng.uniform_array(10000)
    assert us.min() >= 0.0 and us.max() < 1.0
    assert abs(us.mean() - 0.5) < 0.02
    rng2 = D.SplitMix64(7)
    assert np.array_equal(us, rng2.uniform_array(10000))


def test_normal_moments():
    rng = D.SplitMix64(99)
    ns = rng.normal_array(20000)
    assert abs(ns.mean()) < 0.03
    assert abs(ns.std() - 1.0) < 0.03


def test_prototypes_distinct():
    protos = D.prototypes()
    assert protos.shape == (10, 28, 28)
    for a in range(10):
        for b in range(a + 1, 10):
            assert np.abs(protos[a] - protos[b]).mean() > 0.05


def test_samples_deterministic_per_seed():
    img1, y1 = D.sample_indomain(42)
    img2, y2 = D.sample_indomain(42)
    assert np.array_equal(img1, img2) and y1 == y2
    img3, _ = D.sample_indomain(43)
    assert not np.array_equal(img1, img3)


def test_sample_ranges():
    for seed in range(20):
        img, y = D.sample_indomain(seed)
        assert img.shape == (28, 28)
        assert 0 <= y < 10
        assert img.min() >= 0.0 and img.max() <= 1.0
        ood = D.sample_ood(seed)
        assert ood.min() >= 0.0 and ood.max() <= 1.0


def test_ambiguous_is_between_classes():
    """An ambiguous sample should be closer to the blend of its two source
    prototypes than a clean sample is to a wrong prototype."""
    img, y = D.sample_ambiguous(1234)
    protos = D.prototypes()
    dists = [np.abs(img - protos[c]).mean() for c in range(10)]
    # the labelled class should not be a uniquely crisp match
    assert sorted(dists)[1] - sorted(dists)[0] < 0.15


def test_ood_far_from_class_manifold():
    protos = D.prototypes()
    d_in, d_ood = [], []
    for seed in range(30):
        img, y = D.sample_indomain(seed)
        d_in.append(min(np.abs(img - protos[c]).mean() for c in range(10)))
        ood = D.sample_ood(seed)
        d_ood.append(min(np.abs(ood - protos[c]).mean() for c in range(10)))
    assert np.mean(d_ood) > 1.5 * np.mean(d_in)


def test_make_dirty_mnist_shapes():
    d = D.make_dirty_mnist(n_train_clean=50, n_train_amb=20, n_test=10)
    assert d["train_x"].shape == (70, 784)
    assert d["train_y"].shape == (70,)
    assert d["test_ood_y"].tolist() == [-1] * 10
    assert d["train_x"].dtype == np.float32
    # labels cover several classes
    assert len(set(d["train_y"].tolist())) >= 5


def test_derive_seed_streams_differ():
    s = {D.derive_seed(2025, st, 0) for st in range(1, 6)}
    assert len(s) == 5
