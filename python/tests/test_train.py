"""Training pipeline smoke tests: ELBO pieces, Adam, and a tiny end-to-end
SVI run that must learn the synthetic task."""

import numpy as np
import pytest

# Heavyweight dep is optional so the suite stays green offline.
jax = pytest.importorskip("jax", reason="jax not installed (offline CI)")

import jax.numpy as jnp

from compile import data as D
from compile import metrics as M
from compile import model as model_mod
from compile import train as T

jax.config.update("jax_platform_name", "cpu")


def test_gaussian_kl_zero_at_prior():
    mu = jnp.zeros(10)
    sigma = jnp.full(10, T.PRIOR_SIGMA)
    assert abs(float(T.gaussian_kl(mu, sigma, T.PRIOR_SIGMA))) < 1e-6


def test_gaussian_kl_positive():
    rng = np.random.default_rng(0)
    mu = jnp.asarray(rng.normal(size=20).astype(np.float32))
    sigma = jnp.asarray(np.abs(rng.normal(size=20)).astype(np.float32) + 0.01)
    assert float(T.gaussian_kl(mu, sigma, T.PRIOR_SIGMA)) > 0.0


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0]])
    labels = jnp.asarray([0])
    p = np.exp(2.0) / (np.exp(2.0) + 1.0 + np.exp(-1.0))
    assert abs(float(T.cross_entropy(logits, labels)) + np.log(p)) < 1e-5


def test_adam_reduces_quadratic():
    params = {"x": jnp.asarray(5.0)}
    state = T.adam_init(params)
    for _ in range(300):
        grads = {"x": 2.0 * params["x"]}
        params, state = T.adam_update(grads, state, params, lr=0.05)
    assert abs(float(params["x"])) < 0.1


@pytest.fixture(scope="module")
def tiny_run():
    data = D.make_dirty_mnist(n_train_clean=600, n_train_amb=200, n_test=120)
    params, log = T.train_arch("mlp", data, epochs=6, seed=1)
    return data, params, log


def test_loss_decreases(tiny_run):
    _, _, log = tiny_run
    assert log[-1]["nll"] < log[0]["nll"] * 0.6


def test_learns_task(tiny_run):
    data, params, _ = tiny_run
    params_sig = model_mod.params_sigma(params)
    probs = T.svi_predict_probs("mlp", params_sig, data["test_mnist_x"], 8)
    acc = M.accuracy(probs.mean(axis=0), data["test_mnist_y"])
    assert acc > 0.8, f"accuracy {acc}"


def test_ood_detectable(tiny_run):
    data, params, _ = tiny_run
    res = T.evaluate_arch("mlp", params, data)
    assert res["pfp"]["auroc_mi"] > 0.6
    assert res["svi"]["auroc_mi"] > 0.6
    # PFP approximates SVI (paper Table 1: the two stay close)
    assert abs(res["pfp"]["accuracy_mnist"] - res["svi"]["accuracy_mnist"]) < 0.05


def test_kl_annealing_schedule():
    """A(e) rises linearly to ALPHA_MAX across epochs (Eq. 10)."""
    n = 1000
    epochs = 10
    scales = [T.ALPHA_MAX * (e / (epochs - 1)) for e in range(epochs)]
    assert scales[0] == 0.0
    assert abs(scales[-1] - T.ALPHA_MAX) < 1e-9
    assert all(b >= a for a, b in zip(scales, scales[1:]))
