"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and value ranges; fixed-seed cases pin the exact
architectural shapes used by the MLP and LeNet-5.
"""

import numpy as np
import pytest

# Heavyweight deps are optional so the suite stays green offline
# (ISSUE 1: CI must pass without jax/pallas/hypothesis installed).
jax = pytest.importorskip("jax", reason="jax not installed (offline CI)")
pytest.importorskip("hypothesis", reason="hypothesis not installed (offline CI)")

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-4
RTOL = 2e-4


def _close(a, b, atol=ATOL, rtol=RTOL):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


def _gauss_pair(rng, shape, scale=1.0, var_scale=1.0):
    mu = rng.normal(size=shape).astype(np.float32) * scale
    var = np.abs(rng.normal(size=shape)).astype(np.float32) * var_scale + 1e-6
    return jnp.asarray(mu), jnp.asarray(var)


# --------------------------------------------------------------------------
# dense
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 96),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
)
def test_dense_joint_matches_ref(m, k, n, seed, bm, bn):
    rng = np.random.default_rng(seed)
    x_mu, x_var = _gauss_pair(rng, (m, k))
    x_e2 = x_mu * x_mu + x_var
    w_mu, w_var = _gauss_pair(rng, (n, k), scale=0.2, var_scale=0.02)
    w_e2 = w_mu * w_mu + w_var
    got = kernels.pfp_dense_joint(x_mu, x_e2, w_mu, w_e2, block_m=bm, block_n=bn)
    want = ref.pfp_dense_joint(x_mu, x_e2, w_mu, w_e2)
    _close(got[0], want[0])
    _close(got[1], want[1])


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 24), k=st.integers(1, 64), n=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_separate_equals_joint(m, k, n, seed):
    """Fig. 5's two formulations are mathematically identical."""
    rng = np.random.default_rng(seed)
    x_mu, x_var = _gauss_pair(rng, (m, k))
    x_e2 = x_mu * x_mu + x_var
    w_mu, w_var = _gauss_pair(rng, (n, k), scale=0.2, var_scale=0.02)
    w_e2 = w_mu * w_mu + w_var
    a = kernels.pfp_dense_separate(x_mu, x_e2, w_mu, w_e2)
    b = kernels.pfp_dense_joint(x_mu, x_e2, w_mu, w_e2)
    _close(a[0], b[0])
    _close(a[1], b[1])


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 24), k=st.integers(1, 64), n=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_varform_equals_rawmoment(m, k, n, seed):
    """Eq. 7 and Eq. 12 are algebraically the same quantity."""
    rng = np.random.default_rng(seed)
    x_mu, x_var = _gauss_pair(rng, (m, k))
    x_e2 = x_mu * x_mu + x_var
    w_mu, w_var = _gauss_pair(rng, (n, k), scale=0.2, var_scale=0.02)
    w_e2 = w_mu * w_mu + w_var
    a = kernels.pfp_dense_varform(x_mu, x_var, w_mu, w_var)
    b = kernels.pfp_dense_joint(x_mu, x_e2, w_mu, w_e2)
    _close(a[0], b[0])
    _close(a[1], b[1], atol=5e-4, rtol=5e-4)


def test_dense_first_layer_eq13():
    """Generic joint kernel with x_e2=x^2, w_e2=mu^2+var reduces to Eq. 13."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(size=(10, 784)).astype(np.float32))
    w_mu, w_var = _gauss_pair(rng, (100, 784), scale=0.1, var_scale=0.01)
    got = kernels.pfp_dense_first(x, w_mu, w_var)
    want = ref.pfp_dense_first(x, w_mu, w_var)
    _close(got[0], want[0])
    _close(got[1], want[1], atol=5e-4)


def test_dense_bias_paths():
    rng = np.random.default_rng(1)
    x_mu, x_var = _gauss_pair(rng, (4, 16))
    x_e2 = x_mu * x_mu + x_var
    w_mu, w_var = _gauss_pair(rng, (8, 16), scale=0.3, var_scale=0.05)
    w_e2 = w_mu * w_mu + w_var
    b_mu = jnp.asarray(rng.normal(size=8).astype(np.float32))
    b_var = jnp.asarray(np.abs(rng.normal(size=8)).astype(np.float32))
    got = kernels.pfp_dense_joint(x_mu, x_e2, w_mu, w_e2, b_mu, b_var)
    want = ref.pfp_dense_joint(x_mu, x_e2, w_mu, w_e2, b_mu, b_var)
    _close(got[0], want[0])
    _close(got[1], want[1])


def test_dense_variance_nonnegative():
    rng = np.random.default_rng(2)
    x_mu, x_var = _gauss_pair(rng, (16, 32))
    x_e2 = x_mu * x_mu + x_var
    w_mu, w_var = _gauss_pair(rng, (16, 32))
    w_e2 = w_mu * w_mu + w_var
    _, var = kernels.pfp_dense_joint(x_mu, x_e2, w_mu, w_e2)
    assert np.all(np.asarray(var) >= 0.0)


def test_dense_zero_variance_is_deterministic():
    """With zero weight + activation variance, PFP == plain matmul."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(5, 20)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(7, 20)).astype(np.float32))
    mu, var = kernels.pfp_dense_joint(x, x * x, w, w * w)
    _close(mu, x @ w.T)
    assert np.all(np.asarray(var) <= 1e-3)


# --------------------------------------------------------------------------
# ReLU moment matching
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 16), n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.1, 5.0),
)
def test_relu_matches_ref(m, n, seed, scale):
    rng = np.random.default_rng(seed)
    mu, var = _gauss_pair(rng, (m, n), scale=scale, var_scale=scale)
    got = kernels.pfp_relu(mu, var)
    want = ref.pfp_relu(mu, var)
    _close(got[0], want[0])
    _close(got[1], want[1])


def test_relu_against_monte_carlo():
    """Eqs. 8/9 against simulated Gaussian ReLU moments."""
    mu = jnp.asarray(np.array([-2.0, -0.5, 0.0, 0.7, 3.0], np.float32))
    var = jnp.asarray(np.array([0.5, 1.0, 2.0, 0.3, 1.5], np.float32))
    m_ref, e2_ref = ref.relu_mc(mu, var, jax.random.PRNGKey(0), n=400000)
    m, e2 = kernels.pfp_relu(mu, var)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_ref), atol=2e-2)
    np.testing.assert_allclose(np.asarray(e2), np.asarray(e2_ref), atol=6e-2)


def test_relu_raw_moment_dominates_mean_sq():
    """E[x^2] >= E[x]^2 (Jensen) must hold elementwise."""
    rng = np.random.default_rng(7)
    mu, var = _gauss_pair(rng, (8, 32), scale=3.0, var_scale=2.0)
    m, e2 = kernels.pfp_relu(mu, var)
    assert np.all(np.asarray(e2) - np.asarray(m) ** 2 >= -1e-4)


def test_relu_deterministic_limit():
    """var -> 0: moment-matched ReLU -> max(0, mu)."""
    mu = jnp.asarray(np.linspace(-3, 3, 25, dtype=np.float32).reshape(5, 5))
    var = jnp.full((5, 5), 1e-10, jnp.float32)
    m, e2 = kernels.pfp_relu(mu, var)
    want = np.maximum(np.asarray(mu), 0.0)
    np.testing.assert_allclose(np.asarray(m), want, atol=1e-4)
    np.testing.assert_allclose(np.asarray(e2), want * want, atol=1e-4)


# --------------------------------------------------------------------------
# max-pool
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 4), c=st.integers(1, 8),
    h2=st.integers(1, 7), w2=st.integers(1, 7),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_ref(n, c, h2, w2, seed):
    rng = np.random.default_rng(seed)
    mu, var = _gauss_pair(rng, (n, c, 2 * h2, 2 * w2))
    got = kernels.pfp_maxpool2(mu, var)
    want = ref.pfp_maxpool2(mu, var)
    _close(got[0], want[0])
    _close(got[1], want[1])


def test_maxpool_generic_close_to_vectorized():
    """Table 3's two implementations approximate the same max. They are NOT
    bitwise equal: Gaussian moment matching is not associative, and the
    generic reduction folds sequentially while the vectorized k=2 pool uses
    a balanced tree. Both must stay close to each other (and both are
    validated against Monte-Carlo elsewhere)."""
    rng = np.random.default_rng(11)
    mu, var = _gauss_pair(rng, (2, 6, 12, 12))
    a = ref.pfp_maxpool_generic(mu, var, k=2, stride=2)
    b = ref.pfp_maxpool2(mu, var)
    assert float(jnp.mean(jnp.abs(a[0] - b[0]))) < 0.05
    assert float(jnp.mean(jnp.abs(a[1] - b[1]))) < 0.10


def test_gaussian_max_monte_carlo():
    rng = np.random.default_rng(5)
    mu1, mu2 = 0.3, -0.2
    v1, v2 = 0.8, 1.4
    m, v = ref.gaussian_max(jnp.float32(mu1), jnp.float32(v1),
                            jnp.float32(mu2), jnp.float32(v2))
    s = np.maximum(rng.normal(mu1, np.sqrt(v1), 500000),
                   rng.normal(mu2, np.sqrt(v2), 500000))
    assert abs(float(m) - s.mean()) < 5e-3
    assert abs(float(v) - s.var()) < 2e-2


def test_maxpool_deterministic_limit():
    """var -> 0: Gaussian max-pool -> ordinary max-pool."""
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(2, 3, 8, 8)).astype(np.float32))
    var = jnp.full(x.shape, 1e-10, jnp.float32)
    m, v = kernels.pfp_maxpool2(x, var)
    _close(m, ref.det_maxpool2(x), atol=1e-3)


# --------------------------------------------------------------------------
# conv2d
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 3), ci=st.integers(1, 4), co=st.integers(1, 8),
    hw=st.integers(6, 16), k=st.sampled_from([3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_matches_ref(n, ci, co, hw, k, seed):
    rng = np.random.default_rng(seed)
    x_mu, x_var = _gauss_pair(rng, (n, ci, hw, hw))
    x_e2 = x_mu * x_mu + x_var
    w_mu, w_var = _gauss_pair(rng, (co, ci, k, k), scale=0.2, var_scale=0.02)
    w_e2 = w_mu * w_mu + w_var
    got = kernels.pfp_conv2d_joint(x_mu, x_e2, w_mu, w_e2)
    want = ref.pfp_conv2d_joint(x_mu, x_e2, w_mu, w_e2)
    _close(got[0], want[0], atol=5e-4, rtol=5e-4)
    _close(got[1], want[1], atol=1e-3, rtol=1e-3)


def test_conv_first_layer():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.uniform(size=(2, 1, 28, 28)).astype(np.float32))
    w_mu, w_var = _gauss_pair(rng, (6, 1, 5, 5), scale=0.2, var_scale=0.02)
    got = kernels.pfp_conv2d_first(x, w_mu, w_var)
    want = ref.pfp_conv2d_first(x, w_mu, w_var)
    _close(got[0], want[0], atol=5e-4, rtol=5e-4)
    _close(got[1], want[1], atol=1e-3, rtol=1e-3)


def test_conv_vs_dense_equivalence():
    """1x1 image, kxk VALID conv == dense over the flattened patch."""
    rng = np.random.default_rng(9)
    x_mu, x_var = _gauss_pair(rng, (3, 2, 5, 5))
    x_e2 = x_mu * x_mu + x_var
    w_mu, w_var = _gauss_pair(rng, (4, 2, 5, 5), scale=0.3, var_scale=0.03)
    w_e2 = w_mu * w_mu + w_var
    c_mu, c_var = ref.pfp_conv2d_joint(x_mu, x_e2, w_mu, w_e2)
    d_mu, d_var = ref.pfp_dense_joint(
        x_mu.reshape(3, -1), x_e2.reshape(3, -1),
        w_mu.reshape(4, -1), w_e2.reshape(4, -1),
    )
    _close(c_mu[:, :, 0, 0], d_mu)
    _close(c_var[:, :, 0, 0], d_var)
