"""L2 correctness: model-level forward passes, representation discipline,
and PFP/SVI consistency."""

import numpy as np
import pytest

# Heavyweight dep is optional so the suite stays green offline.
jax = pytest.importorskip("jax", reason="jax not installed (offline CI)")

import jax.numpy as jnp

from compile import model as model_mod
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def params():
    out = {}
    for arch in ("mlp", "lenet"):
        p = model_mod.init_params(arch, jax.random.PRNGKey(0), sigma_init=0.05)
        out[arch] = model_mod.params_sigma(p)
    return out


def _x(arch, batch, seed=0):
    rng = np.random.default_rng(seed)
    shape = (batch,) + model_mod.INPUT_SHAPES[arch]
    return jnp.asarray(rng.uniform(size=shape).astype(np.float32))


@pytest.mark.parametrize("arch", ["mlp", "lenet"])
def test_pfp_shapes(params, arch):
    x = _x(arch, 4)
    mu, var = model_mod.pfp_forward(arch, params[arch], x)
    assert mu.shape == (4, 10)
    assert var.shape == (4, 10)
    assert np.all(np.asarray(var) >= 0.0)


@pytest.mark.parametrize("arch", ["mlp", "lenet"])
def test_pallas_path_equals_ref_path(params, arch):
    """The L1-Pallas model graph and the jnp model graph are the same
    function — the core L2 correctness claim behind serving with the jnp
    artifact."""
    x = _x(arch, 2)
    a = model_mod.pfp_forward(arch, params[arch], x, use_pallas=False)
    b = model_mod.pfp_forward(arch, params[arch], x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]),
                               atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("arch", ["mlp", "lenet"])
def test_det_forward_shapes(params, arch):
    w = [(p["w_mu"], p["b_mu"]) for p in params[arch]]
    logits = model_mod.det_forward(arch, w, _x(arch, 3))
    assert logits.shape == (3, 10)


@pytest.mark.parametrize("arch", ["mlp", "lenet"])
def test_pfp_zero_variance_equals_det(params, arch):
    """calib=0 collapses PFP means to the deterministic forward only for
    architectures without maxpool/ReLU nonlinearity coupling; for the MLP
    the means still pass through moment-matched ReLU, so we check the
    zero-variance *limit* instead: sigma -> 0 makes PFP mean -> det."""
    tiny = [
        {
            "w_mu": p["w_mu"],
            "w_sigma": jnp.full_like(p["w_sigma"], 1e-7),
            "b_mu": p["b_mu"],
            "b_sigma": jnp.full_like(p["b_sigma"], 1e-7),
        }
        for p in params[arch]
    ]
    x = _x(arch, 2)
    mu, var = model_mod.pfp_forward(arch, tiny, x)
    w = [(p["w_mu"], p["b_mu"]) for p in params[arch]]
    det = model_mod.det_forward(arch, w, x)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(det),
                               atol=1e-3, rtol=1e-3)
    assert float(jnp.max(var)) < 1e-3


@pytest.mark.parametrize("arch", ["mlp", "lenet"])
def test_pfp_moments_match_svi_sampling(params, arch):
    """PFP's analytic logit moments should approximate the empirical
    moments of many SVI samples (the paper's core approximation claim)."""
    x = _x(arch, 3)
    mu, var = model_mod.pfp_forward(arch, params[arch], x)
    keys = jax.random.split(jax.random.PRNGKey(42), 300)
    fwd = jax.jit(lambda k: model_mod.svi_forward(arch, params[arch], x, k))
    samples = np.stack([np.asarray(fwd(k)) for k in keys])
    emp_mu = samples.mean(axis=0)
    emp_var = samples.var(axis=0)
    # moment matching is approximate; demand correlation, not equality
    np.testing.assert_allclose(np.asarray(mu), emp_mu, atol=0.35, rtol=0.5)
    cc = np.corrcoef(np.asarray(var).ravel(), emp_var.ravel())[0, 1]
    assert cc > 0.7, f"PFP/SVI variance correlation too low: {cc}"


def test_calibration_scales_variance_monotonically(params):
    x = _x("mlp", 2)
    _, v1 = model_mod.pfp_forward("mlp", params["mlp"], x, calib=0.1)
    _, v2 = model_mod.pfp_forward("mlp", params["mlp"], x, calib=1.0)
    assert float(jnp.mean(v2)) > float(jnp.mean(v1))


def test_flat_roundtrip(params):
    """pfp_forward_flat(x, *flat) == pfp_forward with the packed params."""
    arch = "mlp"
    x = _x(arch, 2)
    flat = []
    for p in params[arch]:
        flat += [p["w_mu"], p["w_sigma"] ** 2, p["b_mu"], p["b_sigma"] ** 2]
    a = model_mod.pfp_forward_flat(arch, x, *flat)
    b = model_mod.pfp_forward(arch, params[arch], x)
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]), atol=1e-5)


def test_flat_param_names_order():
    names = model_mod.flat_param_names("mlp", "pfp")
    assert names[:4] == ["l0_w_mu", "l0_w_var", "l0_b_mu", "l0_b_var"]
    assert len(names) == 3 * 4
    det = model_mod.flat_param_names("lenet", "det")
    assert len(det) == 5 * 2


def test_representation_discipline_lenet(params):
    """LeNet alternates conv/relu/pool — exercises every conversion path
    (det->var, var->e2, e2->var) without error and yields finite moments."""
    mu, var = model_mod.pfp_forward("lenet", params["lenet"], _x("lenet", 1))
    assert np.all(np.isfinite(np.asarray(mu)))
    assert np.all(np.isfinite(np.asarray(var)))
