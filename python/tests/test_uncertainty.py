"""Uncertainty metrics (Eqs. 1-3, Eq. 11, AUROC) — including the paper's
Section 3.1 MI-underestimation construction."""

import numpy as np
import pytest

from compile import metrics as M


def test_softmax_normalises():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(5, 7, 10))
    p = M.softmax(logits)
    np.testing.assert_allclose(p.sum(axis=-1), 1.0, atol=1e-6)
    assert np.all(p >= 0)


def test_entropy_bounds():
    uniform = np.full((1, 10), 0.1)
    onehot = np.eye(10)[:1]
    assert abs(M.entropy(uniform)[0] - np.log(10)) < 1e-6
    assert M.entropy(onehot)[0] < 1e-6


def test_decomposition_identity():
    """total = sme + mi must hold exactly (Eq. 3)."""
    rng = np.random.default_rng(1)
    probs = M.softmax(rng.normal(size=(30, 50, 10)))
    u = M.uncertainty_from_probs(probs)
    np.testing.assert_allclose(u["total"], u["sme"] + u["mi"], atol=1e-6)


def test_agreeing_samples_have_zero_mi():
    """Identical samples -> no disagreement -> MI == 0, SME == total."""
    rng = np.random.default_rng(2)
    one = M.softmax(rng.normal(size=(1, 20, 10)))
    probs = np.repeat(one, 25, axis=0)
    u = M.uncertainty_from_probs(probs)
    np.testing.assert_allclose(u["mi"], 0.0, atol=1e-6)
    np.testing.assert_allclose(u["total"], u["sme"], atol=1e-6)


def test_disagreeing_onehots_have_max_mi():
    """Confident but mutually disagreeing predictions (the paper's OOD
    signature): SME ~ 0, MI ~ total."""
    s, n, k = 30, 8, 10
    rng = np.random.default_rng(3)
    classes = rng.integers(0, k, size=(s, n))
    probs = np.full((s, n, k), 1e-9)
    for i in range(s):
        for j in range(n):
            probs[i, j, classes[i, j]] = 1.0
    probs /= probs.sum(-1, keepdims=True)
    u = M.uncertainty_from_probs(probs)
    assert np.all(u["sme"] < 1e-6)
    assert np.all(u["mi"] > 1.0)


def test_mi_underestimation_gaussian_approx():
    """Paper Section 3.1: in an artificial high-epistemic scenario (random
    one-hot class predictions), summarising the logit samples by a Gaussian
    and re-sampling underestimates MI substantially (paper: 44%), while
    total uncertainty stays comparable."""
    s, n, k = 200, 32, 10
    rng = np.random.default_rng(4)
    # random one-hot logits: +8 on a random class, 0 elsewhere
    logits = np.zeros((s, n, k))
    cls = rng.integers(0, k, size=(s, n))
    for i in range(s):
        for j in range(n):
            logits[i, j, cls[i, j]] = 8.0
    true_u = M.uncertainty_from_probs(M.softmax(logits))
    # Gaussian summary of the logit samples (what PFP would report)
    mu = logits.mean(axis=0)
    var = logits.var(axis=0)
    resampled = M.sample_logits_gaussian(mu.astype(np.float32),
                                         var.astype(np.float32), s, seed=0)
    gauss_u = M.uncertainty_from_probs(M.softmax(resampled))
    mi_deficit = 1.0 - gauss_u["mi"].mean() / true_u["mi"].mean()
    assert 0.15 < mi_deficit < 0.9, f"MI deficit {mi_deficit}"
    total_ratio = gauss_u["total"].mean() / true_u["total"].mean()
    assert 0.7 < total_ratio < 1.3


def test_sample_logits_gaussian_moments():
    mu = np.array([[1.0, -2.0]], np.float32)
    var = np.array([[0.25, 4.0]], np.float32)
    s = M.sample_logits_gaussian(mu, var, 20000, seed=5)
    np.testing.assert_allclose(s.mean(axis=0), mu, atol=0.05)
    np.testing.assert_allclose(s.var(axis=0), var, atol=0.15)


def test_auroc_perfect_and_random():
    assert M.auroc(np.array([2.0, 3.0]), np.array([0.0, 1.0])) == 1.0
    assert M.auroc(np.array([0.0, 1.0]), np.array([2.0, 3.0])) == 0.0
    rng = np.random.default_rng(6)
    a = rng.normal(size=2000)
    b = rng.normal(size=2000)
    assert abs(M.auroc(a, b) - 0.5) < 0.03


def test_auroc_with_ties():
    pos = np.array([1.0, 1.0, 2.0])
    neg = np.array([1.0, 0.0, 0.0])
    # pairs: (1,1)x2 ties=0.5 each, rest wins: u = 2*0.5 + 7 = 8 -> 8/9
    assert abs(M.auroc(pos, neg) - 8.0 / 9.0) < 1e-9


def test_accuracy():
    p = np.array([[0.9, 0.1], [0.2, 0.8]])
    assert M.accuracy(p, np.array([0, 1])) == 1.0
    assert M.accuracy(p, np.array([1, 1])) == 0.5
