//! Quickstart: one uncertainty-aware prediction through every layer of
//! the stack.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads the SVI-trained posterior, runs the *single probabilistic forward
//! pass* on an in-domain image and an out-of-domain texture — through both
//! the native Rust operator library and the AOT-compiled XLA artifact —
//! and prints the decomposed uncertainties (Eqs. 1-3).

use pfp::data::DirtyMnist;
use pfp::model::{Arch, PfpExecutor, PosteriorWeights, Schedules};
use pfp::runtime::Engine;
use pfp::uncertainty;

fn main() -> pfp::Result<()> {
    let dir = pfp::artifacts_dir();
    println!("artifacts: {}", dir.display());

    // 1. trained posterior + paper-calibrated variances
    let arch = Arch::mlp();
    let engine = Engine::new(&dir)?;
    let calib = engine.manifest.calibration_factor("mlp");
    let weights = PosteriorWeights::load(&dir, &arch, calib)?;
    println!(
        "loaded {} ({} posterior parameters, calibration factor {})",
        arch.name,
        weights.n_params() * 2, // mu + sigma
        calib
    );

    // 2. evaluation data: one in-domain digit, one OOD texture
    let data = DirtyMnist::load(&dir)?;
    let x_in = data.test_mnist.x.first_rows(1);
    let x_ood = data.test_ood.x.first_rows(1);
    let label = data.test_mnist.y[0];

    // 3a. native operator path (the Table 2-5 code)
    let mut exec = PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1));
    for (name, x, want) in [("in-domain", &x_in, Some(label)), ("OOD", &x_ood, None)] {
        let t = std::time::Instant::now();
        let (mu, var) = exec.forward(x);
        let dt = t.elapsed();
        let u = uncertainty::pfp_uncertainty(&mu, &var, 30, 7);
        let pred = u.mean_p[..10]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        println!("\n[{name}] native PFP forward in {:.3} ms", dt.as_secs_f64() * 1e3);
        println!("  predicted class: {pred}{}",
                 want.map_or(String::new(), |w| format!(" (label {w})")));
        println!(
            "  total={:.3}  aleatoric(SME)={:.3}  epistemic(MI)={:.3}",
            u.total[0], u.sme[0], u.mi[0]
        );
    }

    // 3b. same prediction through the AOT XLA artifact (PJRT runtime)
    let model = engine.load("model_mlp_pfp_b1", &weights)?;
    let t = std::time::Instant::now();
    let outs = model.execute(&x_in)?;
    println!(
        "\n[in-domain] XLA artifact {} in {:.3} ms (platform: {})",
        model.entry.name,
        t.elapsed().as_secs_f64() * 1e3,
        engine.platform()
    );
    let (mu_n, _) = exec.forward(&x_in);
    let max_diff = outs[0].max_abs_diff(&mu_n);
    println!("  native vs XLA logit-mean max |diff|: {max_diff:.2e}");

    println!("\nquickstart OK");
    Ok(())
}
