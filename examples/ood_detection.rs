//! Table 1 / Figs. 3-4 reproduction: SVI vs PFP uncertainty quality on
//! synthetic Dirty-MNIST, from the Rust stack.
//!
//! ```bash
//! cargo run --release --example ood_detection [-- --arch lenet] [--n 500]
//! ```
//!
//! For both methods it reports accuracy, MI-based OOD AUROC (Table 1),
//! per-split uncertainty means with ASCII histograms (Fig. 3), and an
//! SME-vs-MI scatter summary (Fig. 4).

use pfp::data::DirtyMnist;
use pfp::model::{Arch, PfpExecutor, PosteriorWeights, Schedules, SviExecutor};
use pfp::runtime::Manifest;
use pfp::tensor::Tensor;
use pfp::uncertainty::{self, Uncertainty};

fn main() -> pfp::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let arch_name = arg(&args, "--arch").unwrap_or_else(|| "mlp".into());
    let n: usize = arg(&args, "--n").and_then(|v| v.parse().ok()).unwrap_or(400);
    let samples = 30;

    let dir = pfp::artifacts_dir();
    let arch = Arch::by_name(&arch_name)?;
    let manifest = Manifest::load(&dir.join("manifest.json"))?;
    let calib = manifest.calibration_factor(&arch_name);
    let weights = PosteriorWeights::load(&dir, &arch, calib)?;
    let data = DirtyMnist::load(&dir)?;

    let splits: Vec<(&str, Tensor, Vec<i32>)> = vec![
        ("mnist", data.test_mnist.x.first_rows(n), data.test_mnist.y[..n].to_vec()),
        (
            "ambiguous",
            data.test_ambiguous.x.first_rows(n),
            data.test_ambiguous.y[..n].to_vec(),
        ),
        ("ood", data.test_ood.x.first_rows(n), data.test_ood.y[..n].to_vec()),
    ];

    // ---- PFP: single probabilistic pass + Eq. 11 logit sampling --------
    let mut pfp_exec = PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1));
    let mut pfp_u: Vec<(&str, Uncertainty)> = Vec::new();
    let t = std::time::Instant::now();
    for (name, x, _) in &splits {
        let (mu, var) = pfp_exec.forward(x);
        pfp_u.push((name, uncertainty::pfp_uncertainty(&mu, &var, samples, 11)));
    }
    let pfp_time = t.elapsed();

    // ---- SVI baseline: 30 sampled passes --------------------------------
    let mut svi_exec = SviExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1), 3);
    let mut svi_u: Vec<(&str, Uncertainty)> = Vec::new();
    let t = std::time::Instant::now();
    for (name, x, _) in &splits {
        let logits = svi_exec.forward_n(x, samples);
        let k = logits[0].cols();
        let rows = logits[0].rows();
        let mut probs = vec![0.0f32; samples * rows * k];
        for (si, l) in logits.iter().enumerate() {
            let p = uncertainty::softmax(l.data(), k);
            probs[si * rows * k..(si + 1) * rows * k].copy_from_slice(&p);
        }
        svi_u.push((name, uncertainty::uncertainty_from_probs(&probs, samples, rows, k)));
    }
    let svi_time = t.elapsed();

    // ---- Table 1 ---------------------------------------------------------
    println!("== Table 1 — {arch_name} (n={n}/split, {samples} samples, calib={calib}) ==");
    println!(
        "{:<8} {:>12} {:>12} {:>14}",
        "method", "accuracy", "AUROC(MI)", "eval wall"
    );
    for (method, us, wall) in [("SVI", &svi_u, svi_time), ("PFP", &pfp_u, pfp_time)] {
        let acc = uncertainty::accuracy(&us[0].1.mean_p, arch.num_classes(), &splits[0].2);
        let in_mi: Vec<f64> = us[0].1.mi.iter().chain(&us[1].1.mi).cloned().collect();
        let roc = uncertainty::auroc(&us[2].1.mi, &in_mi);
        println!(
            "{:<8} {:>11.1}% {:>12.3} {:>12.1}ms",
            method,
            acc * 100.0,
            roc,
            wall.as_secs_f64() * 1e3
        );
    }
    println!(
        "(paper Table 1: MLP SVI 96.3%/0.812, PFP 96.3%/0.858; LeNet SVI 98.7%/0.986, PFP 98.9%/0.966)"
    );

    // ---- Fig. 3: per-split uncertainty histograms ------------------------
    for (metric, get) in [
        ("Total predictive uncertainty", 0usize),
        ("Softmax entropy (aleatoric)", 1),
        ("Mutual information (epistemic)", 2),
    ] {
        println!("\n== Fig. 3 — {metric} ==");
        for (method, us) in [("SVI", &svi_u), ("PFP", &pfp_u)] {
            for (split, u) in us.iter() {
                let vals = match get {
                    0 => &u.total,
                    1 => &u.sme,
                    _ => &u.mi,
                };
                println!(
                    "  {method:<4} {split:<10} mean={:.3}  {}",
                    mean(vals),
                    histogram(vals, 2.4, 30)
                );
            }
        }
    }

    // ---- Fig. 4: disentanglement summary ---------------------------------
    println!("\n== Fig. 4 — SME vs MI disentanglement (split means) ==");
    println!("{:<6} {:<10} {:>8} {:>8}", "method", "split", "SME", "MI");
    for (method, us) in [("SVI", &svi_u), ("PFP", &pfp_u)] {
        for (split, u) in us.iter() {
            println!(
                "{:<6} {:<10} {:>8.3} {:>8.3}",
                method,
                split,
                mean(&u.sme),
                mean(&u.mi)
            );
        }
    }
    println!(
        "\nExpected shape: ambiguous -> high SME; ood -> high MI; mnist -> low both.\n\
         SVI separates slightly better than PFP (paper Fig. 4)."
    );
    Ok(())
}

fn arg(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

/// ASCII histogram of values in [0, hi) with `bins` buckets.
fn histogram(vals: &[f64], hi: f64, bins: usize) -> String {
    let mut counts = vec![0usize; bins];
    for &v in vals {
        let b = ((v / hi) * bins as f64) as usize;
        counts[b.min(bins - 1)] += 1;
    }
    let max = counts.iter().cloned().max().unwrap_or(1).max(1);
    counts
        .iter()
        .map(|&c| {
            let level = (c * 8 + max - 1) / max;
            [' ', '.', ':', '-', '=', '+', '*', '#', '@'][level.min(8)]
        })
        .collect()
}
