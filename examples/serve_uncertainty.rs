//! End-to-end serving driver (the EXPERIMENTS.md validation run).
//!
//! ```bash
//! cargo run --release --example serve_uncertainty [-- --backend xla] [--requests 300]
//! ```
//!
//! Boots the full coordinator (TCP server + dynamic batcher + PFP backend
//! on the trained posterior), fires a mixed in-domain/OOD request stream
//! from concurrent TCP clients, and reports the paper's headline system
//! metrics: per-request latency (p50/p95), throughput, accuracy, OOD
//! flagging quality, and batch occupancy.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use pfp::coordinator::{
    protocol, NativePfpBackend, Server, ServerConfig, Service, XlaPfpBackend,
};
use pfp::data::DirtyMnist;
use pfp::model::{Arch, PfpExecutor, PosteriorWeights, Schedules};
use pfp::runtime::{Engine, Manifest};
use pfp::uncertainty;

fn main() -> pfp::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let backend_kind = arg(&args, "--backend").unwrap_or_else(|| "native".into());
    let arch_name = arg(&args, "--arch").unwrap_or_else(|| "mlp".into());
    let n_requests: usize =
        arg(&args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(300);
    let clients: usize = arg(&args, "--clients").and_then(|v| v.parse().ok()).unwrap_or(4);

    let dir = pfp::artifacts_dir();
    let arch = Arch::by_name(&arch_name)?;
    let manifest = Manifest::load(&dir.join("manifest.json"))?;
    let calib = manifest.calibration_factor(&arch_name);
    let weights = PosteriorWeights::load(&dir, &arch, calib)?;
    let data = Arc::new(DirtyMnist::load(&dir)?);

    // ---- calibrate the serving OOD threshold on a held-out slice --------
    let mut exec = PfpExecutor::new(arch.clone(), weights.clone(), Schedules::tuned(1));
    let (mu_i, var_i) = exec.forward(&data.test_mnist.x.first_rows(128));
    let (mu_o, var_o) = exec.forward(&data.test_ood.x.first_rows(128));
    let mi_in_all = uncertainty::pfp_uncertainty(&mu_i, &var_i, 30, 1).mi;
    let mi_ood_all = uncertainty::pfp_uncertainty(&mu_o, &var_o, 30, 1).mi;
    let (mi_in, mi_ood) = (mean(&mi_in_all), mean(&mi_ood_all));
    // threshold at the in-domain p95: caps the false-positive rate at ~5%
    // while keeping recall high (MI distributions barely overlap)
    let threshold = pfp::util::stats::percentile(&mi_in_all, 95.0).max(1e-4);
    println!(
        "OOD threshold calibrated: MI_in={mi_in:.4} MI_ood={mi_ood:.4} -> p95_in={threshold:.4}"
    );

    // ---- boot the server -------------------------------------------------
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        ood_threshold: threshold,
        ..Default::default()
    };
    let mut svc = Service::new(cfg);
    let backend: Box<dyn pfp::coordinator::Backend> = match backend_kind.as_str() {
        "xla" => {
            let engine: &'static Engine = Box::leak(Box::new(Engine::new(&dir)?));
            Box::new(XlaPfpBackend::new(engine, &arch_name, &weights)?)
        }
        _ => Box::new(NativePfpBackend::new(arch.clone(), weights, Schedules::tuned(1))),
    };
    let bname = backend.name();
    svc.register(&arch_name, arch.input_len(), backend);
    let svc = Arc::new(svc);
    let server = Server::bind(svc.clone())?;
    let addr = server.addr;
    std::thread::spawn(move || server.run());
    println!("server up at {addr} (backend: {bname})");

    // ---- mixed request stream from concurrent clients --------------------
    let per_client = n_requests / clients;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let data = data.clone();
        let arch_name = arch_name.clone();
        handles.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            stream.set_nodelay(true).unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut reader = BufReader::new(stream);
            let mut results = Vec::new();
            for i in 0..per_client {
                let global = c * per_client + i;
                // every 3rd request is OOD
                let is_ood = global % 3 == 2;
                let (x, label) = if is_ood {
                    (data.test_ood.x.row(global % 900), -1)
                } else {
                    (
                        data.test_mnist.x.row(global % 900),
                        data.test_mnist.y[global % 900],
                    )
                };
                let t = Instant::now();
                writeln!(writer, "{}", protocol::request_json(global as u64, &arch_name, x))
                    .unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let lat_us = t.elapsed().as_secs_f64() * 1e6;
                let resp = protocol::Response::parse(line.trim()).unwrap();
                results.push((is_ood, label, resp, lat_us));
            }
            results
        }));
    }
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();

    // ---- report -----------------------------------------------------------
    let mut lats: Vec<f64> = all.iter().map(|r| r.3).collect();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (mut correct, mut n_in, mut tp, mut fp, mut n_ood) = (0, 0, 0, 0, 0);
    for (is_ood, label, resp, _) in &all {
        let p = resp.result.as_ref().expect("inference ok");
        if *is_ood {
            n_ood += 1;
            tp += p.ood as usize;
        } else {
            n_in += 1;
            fp += p.ood as usize;
            if p.pred == *label {
                correct += 1;
            }
        }
    }
    println!("\n== end-to-end serving results ({}) ==", bname);
    println!("requests: {} over {clients} clients in {wall:.2}s", all.len());
    println!("throughput: {:.0} req/s", all.len() as f64 / wall);
    println!(
        "latency: p50={:.2}ms p95={:.2}ms p99={:.2}ms",
        pct(&lats, 50.0) / 1e3,
        pct(&lats, 95.0) / 1e3,
        pct(&lats, 99.0) / 1e3
    );
    println!(
        "accuracy (in-domain): {:.1}% ({correct}/{n_in})",
        100.0 * correct as f64 / n_in as f64
    );
    println!(
        "OOD flagging: recall {:.1}% ({tp}/{n_ood}), false-positive rate {:.1}% ({fp}/{n_in})",
        100.0 * tp as f64 / n_ood as f64,
        100.0 * fp as f64 / n_in as f64
    );
    println!("server metrics: {}", svc.metrics.snapshot().dump());
    Ok(())
}

fn arg(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx]
}
