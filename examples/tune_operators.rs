//! Auto-tuning walkthrough (the paper's Section 6.2/6.3 workflow).
//!
//! ```bash
//! cargo run --release --example tune_operators [-- --trials 24]
//! ```
//!
//! Runs the Meta-Scheduler-analog search over the PFP dense and conv
//! schedules for the MLP and LeNet-5 hot layers, prints the incumbent
//! trajectory, and persists tuning records that `pfp serve` / the benches
//! pick up.

use pfp::model::{Arch, PosteriorWeights};
use pfp::ops::conv::{pfp_conv2d_joint, ConvArgs};
use pfp::ops::dense::{pfp_dense_joint, DenseArgs};
use pfp::runtime::Manifest;
use pfp::tensor::{ProbTensor, Rep, Tensor};
use pfp::tuner::{self, SearchSpace, TuneOpts, TuningRecords};

fn main() -> pfp::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let trials: usize = args
        .iter()
        .position(|a| a == "--trials")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);

    let dir = pfp::artifacts_dir();
    let manifest = Manifest::load(&dir.join("manifest.json"))?;
    let batch = 10;
    let space = SearchSpace::dense_default(pfp::util::threadpool::default_threads());
    let opts = TuneOpts { random_trials: trials, ..Default::default() };
    let mut records = TuningRecords::load_or_default(&dir.join("tuning/records.json"));

    // ---- MLP Dense 1 (the paper's Table 2 operator) ----------------------
    {
        let arch = Arch::mlp();
        let w = PosteriorWeights::load(&dir, &arch, manifest.calibration_factor("mlp"))?;
        let lw = &w.layers[0];
        let x = Tensor::full(vec![batch, 784], 0.5);
        let x_e2 = x.squared();
        println!("tuning mlp/dense1 [{}x784x100], {trials} random trials + evolution ...", batch);
        let res = tuner::tune(&space, opts, |s| {
            let _ = pfp_dense_joint(
                &DenseArgs {
                    x_mu: &x, x_aux: &x_e2,
                    w_mu: &lw.w_mu, w_aux: &lw.w_e2,
                    b_mu: Some(lw.b_mu.data()), b_var: Some(lw.b_var.data()),
                },
                s,
            );
        });
        report("mlp dense1", &res);
        records.insert(TuningRecords::key("dense", "mlp", batch), res.best, res.best_ms);
    }

    // ---- LeNet Conv2d 2 (the dominant LeNet layer, Table 4) --------------
    {
        let arch = Arch::lenet();
        let w = PosteriorWeights::load(&dir, &arch, manifest.calibration_factor("lenet"))?;
        let lw = &w.layers[1]; // conv2: 16@5x5 over 6x12x12
        let x_mu = Tensor::full(vec![batch, 6, 12, 12], 0.4);
        let x = ProbTensor::new(x_mu.clone(), x_mu.squared(), Rep::E2);
        println!("\ntuning lenet/conv2 [{}x6x12x12 -> 16@5x5] ...", batch);
        let res = tuner::tune(&space, opts, |s| {
            let _ = pfp_conv2d_joint(
                &x,
                &ConvArgs {
                    w_mu: &lw.w_mu, w_aux: &lw.w_e2,
                    b_mu: Some(lw.b_mu.data()), b_var: Some(lw.b_var.data()),
                },
                s,
            );
        });
        report("lenet conv2", &res);
        records.insert(TuningRecords::key("conv", "lenet", batch), res.best, res.best_ms);
    }

    let path = dir.join("tuning/records.json");
    records.save(&path)?;
    println!("\ntuning records saved to {}", path.display());
    Ok(())
}

fn report(name: &str, res: &tuner::TuneResult) {
    println!("== {name} ==");
    println!(
        "  baseline {:.3}ms -> best {:.3}ms  ({:.2}x speedup)  schedule: {}",
        res.baseline_ms,
        res.best_ms,
        res.speedup(),
        res.best.tag()
    );
    // incumbent trajectory
    let mut best_so_far = f64::INFINITY;
    let mut shown = 0;
    for (i, t) in res.trials.iter().enumerate() {
        if t.median_ms < best_so_far {
            best_so_far = t.median_ms;
            println!(
                "  trial {i:>3}: {:>8.3}ms  {}",
                t.median_ms,
                t.schedule.tag()
            );
            shown += 1;
            if shown > 12 {
                break;
            }
        }
    }
}
