# Developer entry points. `make verify` is tier-1 and byte-identical to
# what CI's build+test jobs run, so local green == CI green.

.PHONY: verify build test test-scalar test-native-cpu bench bench-build fmt clippy lint model-check miri python-test artifacts clean

# ---- tier-1 --------------------------------------------------------------
# (plus the examples + serving/plan bench compile gates, mirroring CI,
# plus the static-analysis gates: project lints + concurrency models)
verify:
	cargo build --release
	cargo test -q
	cargo build --examples
	cargo bench --no-run --bench pipeline_throughput
	cargo bench --no-run --bench plan_vs_interpreter
	cargo bench --no-run --bench plan_parallel_scaling
	cargo bench --no-run --bench simd_kernels
	cargo bench --no-run --bench registry_churn
	cargo bench --no-run --bench connection_scaling
	$(MAKE) lint
	$(MAKE) model-check

# both runtime dispatch branches, exactly as CI's test matrix runs them
test-scalar:
	PFP_FORCE_SCALAR=1 cargo test -q

test-native-cpu:
	RUSTFLAGS=-Ctarget-cpu=native cargo test -q

build:
	cargo build --release

test:
	cargo test -q

# ---- quality gates (same commands as CI) ---------------------------------
fmt:
	cargo fmt --all -- --check

clippy:
	cargo clippy --all-targets -- -D warnings

# project-invariant lints: SAFETY comments, hot-path allocation freedom,
# schema-version consistency, bench gate coverage (rust/src/verify/lint.rs)
lint:
	cargo run --bin pfp-lint

# exhaustive interleaving exploration of the unsafe concurrency protocols
# + the seeded-mutant detection corpus (rust/src/verify/)
model-check:
	cargo test -q --features model_check verify::
	cargo test -q --features model_check --test model_check

# unsafe-heavy subset under the miri interpreter (nightly toolchain)
miri:
	MIRIFLAGS=-Zmiri-disable-isolation cargo +nightly miri test --lib util::threadpool
	MIRIFLAGS=-Zmiri-disable-isolation cargo +nightly miri test --lib util::mmap
	MIRIFLAGS=-Zmiri-disable-isolation cargo +nightly miri test --lib tensor::
	MIRIFLAGS=-Zmiri-disable-isolation cargo +nightly miri test --lib verify::shim

# ---- benchmarks ----------------------------------------------------------
# compile-only (the CI gate): every Table/Fig reproduction must build
bench-build:
	cargo bench --no-run

# fast smoke pass over all benches (seconds, not minutes)
bench:
	PFP_BENCH_FAST=1 cargo bench

# ---- python (L1/L2) ------------------------------------------------------
python-test:
	python3 -m pytest python/tests -q

# Train + AOT-lower the artifacts the integration tests/benches consume
# (requires jax; the Rust suite skips gracefully when these are absent).
artifacts:
	cd python && python3 -m compile.train --out ../artifacts
	cd python && python3 -m compile.aot --out ../artifacts

clean:
	cargo clean
